"""`repro.analysis` — fixture tests: every rule fires on its known-bad
snippet, stays silent on the known-good twin, and `# noqa: RA###`
suppresses it; plus registry/baseline mechanics, the PR-5 arrival-order
regression fixture, a self-run asserting the analyzer's own code is
clean, and the repo-wide gate (`src tests` + baseline → zero findings).
"""

from __future__ import annotations

import json
import os
import textwrap

import pytest

from repro.analysis import (analyze_contexts, analyze_paths, analyze_source,
                            load_baseline, save_baseline)
from repro.analysis.baseline import BaselineError, apply_baseline
from repro.analysis.core import FileContext, file_scopes

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CONSENSUS_PATH = "src/repro/core/tally_helper.py"
CRYPTO_PATH = "src/repro/core/crypto/helper.py"
NEUTRAL_PATH = "src/repro/launch/helper.py"


def codes(report):
    return [f.rule for f in report.findings]


def run(src, path=CONSENSUS_PATH):
    return analyze_source(textwrap.dedent(src), path=path)


# ---------------------------------------------------------------------------
# scoping
# ---------------------------------------------------------------------------

def test_file_scopes():
    assert "consensus" in file_scopes("src/repro/core/hcds.py")
    assert "consensus" in file_scopes("src/repro/blockchain/ledger.py")
    assert "consensus" in file_scopes("src/repro/sim/network.py")
    assert "consensus" not in file_scopes("src/repro/fl/client.py")
    assert "rng" in file_scopes("benchmarks/bench_hcds.py")
    assert "crypto" in file_scopes("src/repro/core/crypto/field.py")
    assert "crypto" in file_scopes("src/repro/core/envelope.py")
    assert "crypto" in file_scopes("src/repro/core/phases.py")
    assert "crypto" not in file_scopes("src/repro/core/btsv.py")
    assert "tests" in file_scopes("tests/test_hcds.py")
    assert "repro" in file_scopes("src/repro/core/hcds.py")
    assert "repro" not in file_scopes("benchmarks/bench_hcds.py")


# ---------------------------------------------------------------------------
# RA1xx — determinism
# ---------------------------------------------------------------------------

def test_ra101_fires_on_global_numpy_rng():
    bad = """
        import numpy as np
        def pick_round():
            return np.random.randint(1 << 30)
    """
    assert codes(run(bad)) == ["RA101"]


def test_ra101_fires_on_stdlib_random_module():
    bad = """
        import random
        def jitter():
            return random.random()
    """
    assert codes(run(bad)) == ["RA101"]


def test_ra101_good_seeded_generator_silent():
    good = """
        import numpy as np
        def pick_round(seed):
            rng = np.random.default_rng(seed)
            return int(rng.integers(1 << 30))
    """
    assert codes(run(good)) == []


def test_ra101_out_of_scope_module_silent():
    bad = """
        import numpy as np
        def pick():
            return np.random.randint(10)
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == []


def test_ra101_noqa_suppresses():
    bad = """
        import numpy as np
        def pick():
            return np.random.randint(10)  # noqa: RA101
    """
    report = run(bad)
    assert codes(report) == []
    assert [f.rule for f in report.suppressed] == ["RA101"]


def test_ra102_fires_on_wall_clock():
    bad = """
        import time
        def deadline():
            return time.time() + 60.0
    """
    assert codes(run(bad)) == ["RA102"]


def test_ra102_perf_counter_silent():
    good = """
        import time
        def stopwatch():
            return time.perf_counter()
    """
    assert codes(run(good)) == []


def test_ra103_fires_on_pr5_arrival_order_pattern():
    # the PR-5 bug class: precedence assigned by iterating an unordered
    # collection of committers — two nodes disagree on who owns a model
    bad = """
        def finalize_commit_order(commits):
            order = {}
            for nid in {c.node_id for c in commits}:
                order[nid] = len(order)
            return order
    """
    assert codes(run(bad)) == ["RA103"]


def test_ra103_sorted_iteration_silent():
    good = """
        def finalize_commit_order(commits):
            order = {}
            for nid in sorted({c.node_id for c in commits}):
                order[nid] = len(order)
            return order
    """
    assert codes(run(good)) == []


def test_ra103_tracks_local_set_variables():
    bad = """
        def tally_order(votes):
            voters = set(votes)
            return [v for v in voters]
    """
    assert codes(run(bad)) == ["RA103"]


def test_ra103_membership_tests_silent():
    good = """
        def tally(votes, quorum_ids):
            members = set(quorum_ids)
            return [v for v in votes if v in members]
    """
    assert codes(run(good)) == []


# ---------------------------------------------------------------------------
# RA15x — observability hooks must be read-only
# ---------------------------------------------------------------------------

OBS_PATH = "src/repro/obs/helper.py"


def test_ra151_fires_on_mutating_registered_hook():
    bad = """
        def evict_on_phase(phase, ctx):
            ctx.rejected[0] = "nope"

        def install(consensus):
            consensus.add_phase_hook("*", evict_on_phase, when="after")
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA151"]


def test_ra151_fires_on_mutating_lambda_hook():
    bad = """
        def install(consensus):
            consensus.add_phase_hook(
                "Tally", lambda phase, ctx: ctx.votes.clear())
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA151"]


def test_ra151_fires_on_mutator_call_through_env():
    bad = """
        def watch(phase, ctx):
            ctx.env.note("peek", round=ctx.round)

        def install(consensus):
            consensus.add_phase_hook("*", watch)
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA151"]


def test_ra151_read_only_hook_silent():
    good = """
        def watch(phase, ctx):
            print(phase, ctx.round, len(ctx.commitments),
                  ctx.env.network.now if ctx.env else None)

        def install(consensus):
            consensus.add_phase_hook("*", watch, when="after")
    """
    assert codes(run(good, path=NEUTRAL_PATH)) == []


def test_ra151_unregistered_function_silent_outside_obs():
    # same mutation, but the function is never registered as a hook and
    # the file is not in the obs package — protocol code may mutate ctx
    good = """
        def evict(phase, ctx):
            ctx.rejected[0] = "nope"
    """
    assert codes(run(good, path=NEUTRAL_PATH)) == []


def test_ra151_obs_package_ctx_mutation_fires():
    bad = """
        def snapshot(rec, ctx):
            ctx.votes.clear()
            return dict(ctx.commitments)
    """
    assert codes(run(bad, path=OBS_PATH)) == ["RA151"]


def test_ra151_obs_package_read_only_silent():
    good = """
        def snapshot(rec, ctx, env):
            rec.observe("votes", len(ctx.votes))
            return env.network.now
    """
    assert codes(run(good, path=OBS_PATH)) == []


def test_ra151_obs_package_own_state_mutation_silent():
    good = """
        def record(rec, value):
            rec.spans.append(value)
            rec.metrics.update({"x": 1})
    """
    assert codes(run(good, path=OBS_PATH)) == []


def test_ra151_noqa_suppresses():
    bad = """
        def install(consensus):
            consensus.add_phase_hook(
                "*", lambda phase, ctx: ctx.votes.clear())  # noqa: RA151
    """
    report = run(bad, path=NEUTRAL_PATH)
    assert codes(report) == []
    assert [f.rule for f in report.suppressed] == ["RA151"]


def test_ra151_tests_scope_silent():
    bad = """
        def fake_hook(phase, ctx):
            ctx.votes.clear()

        def install(consensus):
            consensus.add_phase_hook("*", fake_hook)
    """
    assert codes(run(bad, path="tests/test_hooks.py")) == []


# ---------------------------------------------------------------------------
# RA2xx — constant-time crypto
# ---------------------------------------------------------------------------

def test_ra201_fires_on_digest_equality():
    bad = """
        def check(reveal_digest, commitment):
            if reveal_digest != commitment.digest:
                return False
            return True
    """
    assert codes(run(bad, path=CRYPTO_PATH)) == ["RA201"]


def test_ra201_fires_on_tuple_wrapped_tag_compare():
    bad = """
        def same_tag(r, c):
            return tuple(r.tag) == tuple(c.tag)
    """
    assert codes(run(bad, path=CRYPTO_PATH)) == ["RA201"]


def test_ra201_compare_digest_silent():
    good = """
        import hmac
        def check(reveal_digest, commitment):
            return hmac.compare_digest(reveal_digest, commitment.digest)
    """
    assert codes(run(good, path=CRYPTO_PATH)) == []


def test_ra201_out_of_crypto_scope_silent():
    bad = """
        def check(a_digest, b_digest):
            return a_digest == b_digest
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == []


def test_ra201_structural_guards_silent():
    good = """
        def valid(tag):
            return len(tag) == 65 and tag is not None
    """
    assert codes(run(good, path=CRYPTO_PATH)) == []


def test_ra202_fires_on_secret_dependent_branch():
    bad = """
        def sign(digest, private_key):
            if private_key > 100:
                return fast_path(digest)
            return slow_path(digest)
    """
    assert codes(run(bad, path=CRYPTO_PATH)) == ["RA202"]


def test_ra203_fires_on_secret_multiplication():
    bad = """
        def sign_s(z, r, k_inv, private_key, N):
            return k_inv * (z + r * private_key) % N
    """
    assert codes(run(bad, path=CRYPTO_PATH)) == ["RA203"]


def test_ra203_public_arithmetic_silent():
    good = """
        def verify_u(z, r, w, N):
            return (z * w % N, r * w % N)
    """
    assert codes(run(good, path=CRYPTO_PATH)) == []


def test_ra2xx_noqa_suppresses():
    bad = """
        def check(a_digest, b_digest):
            return a_digest == b_digest  # noqa: RA201
    """
    report = run(bad, path=CRYPTO_PATH)
    assert codes(report) == []
    assert [f.rule for f in report.suppressed] == ["RA201"]


# ---------------------------------------------------------------------------
# RA3xx — JAX tracing hygiene
# ---------------------------------------------------------------------------

def test_ra301_fires_on_print_in_jit():
    bad = """
        import jax
        @jax.jit
        def step(x):
            print("tracing", x)
            return x * 2
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA301"]


def test_ra301_fires_on_closure_mutation_in_scan_body():
    bad = """
        import jax
        from jax import lax
        trace_log = []
        def body(carry, x):
            trace_log.append(x)
            return carry + x, x
        def run(xs):
            return lax.scan(body, 0.0, xs)
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA301"]


def test_ra301_local_mutation_silent():
    good = """
        import jax
        @jax.jit
        def step(x):
            acc = []
            acc.append(x)
            return acc[0] * 2
    """
    assert codes(run(good, path=NEUTRAL_PATH)) == []


def test_ra302_fires_on_python_cast_of_tracer():
    bad = """
        import jax
        @jax.jit
        def step(x):
            return float(x) * 2
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA302"]


def test_ra302_cast_outside_traced_fn_silent():
    good = """
        def host_side(x):
            return float(x) * 2
    """
    assert codes(run(good, path=NEUTRAL_PATH)) == []


def test_ra303_fires_on_non_literal_static_argnames():
    bad = """
        import jax, functools
        NAMES = compute_names()
        @functools.partial(jax.jit, static_argnames=NAMES)
        def step(x, mode):
            return x
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA303"]


def test_ra303_literal_static_argnames_silent():
    good = """
        import jax, functools
        @functools.partial(jax.jit, static_argnames=("mode", "block"))
        def step(x, mode, block):
            return x
    """
    assert codes(run(good, path=NEUTRAL_PATH)) == []


def test_ra304_fires_on_global_x64_flip():
    bad = """
        import jax
        jax.config.update("jax_enable_x64", True)
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA304"]


def test_ra304_scoped_enable_x64_silent():
    good = """
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        def limbs(x):
            with enable_x64():
                return jnp.asarray(x, jnp.float64)
    """
    assert codes(run(good, path=NEUTRAL_PATH)) == []


def test_ra304_fires_on_unscoped_float64():
    bad = """
        import jax.numpy as jnp
        def limbs(x):
            return jnp.asarray(x, jnp.float64)
    """
    assert codes(run(bad, path=NEUTRAL_PATH)) == ["RA304"]


# ---------------------------------------------------------------------------
# RA4xx — domain separation
# ---------------------------------------------------------------------------

def test_ra401_fires_on_unregistered_kind():
    bad = """
        from repro.core.envelope import SignedEnvelope
        def announce(round, sender, digest, sk):
            return SignedEnvelope.seal("gossip", round, sender, digest, sk)
    """
    assert codes(run(bad)) == ["RA401"]


def test_ra401_registered_kind_silent():
    good = """
        from repro.core.envelope import SignedEnvelope
        def announce(round, sender, digest, sk):
            return SignedEnvelope.seal("vote", round, sender, digest, sk)
    """
    assert codes(run(good)) == []


def test_ra402_fires_on_non_literal_kind():
    bad = """
        from repro.core.envelope import SignedEnvelope
        def announce(kind, round, sender, digest, sk):
            return SignedEnvelope.seal(kind, round, sender, digest, sk)
    """
    assert codes(run(bad)) == ["RA402"]


def test_ra403_fires_on_raw_digest_dsign():
    bad = """
        from repro.core import crypto
        def sign_gossip(payload, sk):
            return crypto.dsign(crypto.sha256_digest(payload), sk)
    """
    assert codes(run(bad)) == ["RA403"]


def test_ra403_domained_dsign_silent():
    good = """
        from repro.core import crypto
        from repro.core.envelope import signing_digest
        def sign_vote(round, sender, payload_digest, sk):
            return crypto.dsign(
                signing_digest("vote", round, sender, payload_digest), sk)
    """
    assert codes(run(good)) == []


def test_ra403_out_of_repro_scope_silent():
    bad = """
        from repro.core import crypto
        def bench_sign(payload, sk):
            return crypto.dsign(crypto.sha256_digest(payload), sk)
    """
    assert codes(run(bad, path="benchmarks/bench_sign.py")) == []


def test_ra404_fires_on_duplicate_registry_kind():
    fake_registry = textwrap.dedent("""
        KINDS = ("commit", "reveal", "vote", "vote")
        _DOMAIN = b"pofel-envelope-v1"
    """)
    ctx = FileContext.parse(fake_registry, "src/repro/core/envelope.py")
    report = analyze_contexts([ctx])
    assert "RA404" in codes(report)


def test_ra404_fires_on_domain_tag_redefined_elsewhere():
    registry = textwrap.dedent("""
        KINDS = ("commit", "reveal", "vote", "block")
        _DOMAIN = b"pofel-envelope-v1"
    """)
    offender = textwrap.dedent("""
        GOSSIP_DOMAIN = b"pofel-envelope-v1"
    """)
    ctxs = [FileContext.parse(registry, "src/repro/core/envelope.py"),
            FileContext.parse(offender, "src/repro/core/gossip.py")]
    report = analyze_contexts(ctxs)
    assert "RA404" in codes(report)
    assert any(f.path.endswith("gossip.py") for f in report.findings)


# ---------------------------------------------------------------------------
# noqa mechanics
# ---------------------------------------------------------------------------

def test_bare_noqa_suppresses_everything_on_line():
    bad = """
        import numpy as np
        def pick():
            return np.random.randint(10)  # noqa
    """
    report = run(bad)
    assert codes(report) == []
    assert len(report.suppressed) == 1


def test_noqa_for_other_rule_does_not_suppress():
    bad = """
        import numpy as np
        def pick():
            return np.random.randint(10)  # noqa: RA201
    """
    assert codes(run(bad)) == ["RA101"]


def test_noqa_inside_string_literal_is_inert():
    bad = '''
        import numpy as np
        def pick():
            doc = "suppress with # noqa: RA101"
            return np.random.randint(10)
    '''
    assert codes(run(bad)) == ["RA101"]


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------

def test_baseline_grandfathers_matching_finding(tmp_path):
    bad = textwrap.dedent("""
        import numpy as np
        def pick():
            return np.random.randint(10)
    """)
    report = run(bad)
    assert codes(report) == ["RA101"]
    path = tmp_path / "baseline.json"
    save_baseline(str(path), report.findings, justification="known legacy")
    entries = load_baseline(str(path))
    kept, grandfathered, stale = apply_baseline(report.findings, entries)
    assert kept == [] and len(grandfathered) == 1 and stale == []


def test_baseline_dies_when_flagged_line_changes(tmp_path):
    bad = textwrap.dedent("""
        import numpy as np
        def pick():
            return np.random.randint(10)
    """)
    report = run(bad)
    path = tmp_path / "baseline.json"
    save_baseline(str(path), report.findings, justification="legacy")
    entries = load_baseline(str(path))
    changed = run(bad.replace("randint(10)", "randint(99)"))
    kept, grandfathered, stale = apply_baseline(changed.findings, entries)
    assert len(kept) == 1 and grandfathered == [] and len(stale) == 1


def test_baseline_requires_justification(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 1, "entries": [{
        "rule": "RA101", "path": "x.py", "snippet": "y",
        "justification": "   "}]}))
    with pytest.raises(BaselineError, match="justification"):
        load_baseline(str(path))


def test_baseline_rejects_placeholder_justification(tmp_path):
    """The save_baseline default ("TODO: justify or fix") must not pass the
    loader — regenerating the baseline alone can never silence the gate."""
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 1, "entries": [{
        "rule": "RA101", "path": "x.py", "snippet": "y",
        "justification": "TODO: justify or fix"}]}))
    with pytest.raises(BaselineError, match="placeholder justification"):
        load_baseline(str(path))
    # case and padding don't smuggle it through either
    path.write_text(json.dumps({"version": 1, "entries": [{
        "rule": "RA101", "path": "x.py", "snippet": "y",
        "justification": "  todo — fill this in later  "}]}))
    with pytest.raises(BaselineError, match="placeholder justification"):
        load_baseline(str(path))


def test_baseline_roundtrip_of_fresh_save_is_rejected(tmp_path):
    """save_baseline's own default output must fail load_baseline until a
    human edits the justification in."""
    bad = textwrap.dedent("""
        import numpy as np
        def pick():
            return np.random.randint(10)
    """)
    report = run(bad)
    path = tmp_path / "baseline.json"
    save_baseline(str(path), report.findings)   # default placeholder
    with pytest.raises(BaselineError, match="placeholder justification"):
        load_baseline(str(path))


def test_repo_baseline_entries_all_carry_justifications():
    entries = load_baseline(os.path.join(REPO_ROOT,
                                         "analysis-baseline.json"))
    assert entries, "repo baseline should record the deliberate exceptions"
    for e in entries:
        assert len(e.justification.strip()) > 20


# ---------------------------------------------------------------------------
# self-run + the repo gate
# ---------------------------------------------------------------------------

def test_analyzer_is_clean_on_itself():
    report = analyze_paths(["src/repro/analysis"], root=REPO_ROOT)
    assert report.files_analyzed >= 7
    assert report.findings == [] and report.errors == []


def test_repo_gate_src_tests_is_clean():
    """The acceptance criterion: `python -m repro.analysis src tests`
    exits 0 — zero unsuppressed findings with the checked-in baseline,
    and no stale baseline entries."""
    baseline = load_baseline(os.path.join(REPO_ROOT,
                                          "analysis-baseline.json"))
    report = analyze_paths(["src", "tests", "benchmarks"], root=REPO_ROOT,
                           baseline=baseline)
    assert report.errors == []
    assert report.findings == [], [f"{f.path}:{f.line} {f.rule}"
                                   for f in report.findings]
    assert report.stale_baseline == []
    # the known deliberate exceptions are recorded, not silently absent
    assert {f.rule for f in report.grandfathered} == {"RA203"}


def test_cli_exit_codes(tmp_path, capsys):
    from repro.analysis.__main__ import main
    bad = tmp_path / "src" / "repro" / "core" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nROUND = np.random.randint(9)\n")
    old = os.getcwd()
    os.chdir(tmp_path)
    try:
        assert main(["src", "--format", "text"]) == 1
        assert main(["src", "--format", "github"]) == 1
        out = capsys.readouterr().out
        assert "::error file=src/repro/core/bad.py" in out
        bad.write_text("import numpy as np\n"
                       "RNG = np.random.default_rng(0)\n")
        assert main(["src"]) == 0
        assert main(["nonexistent-dir"]) == 2
    finally:
        os.chdir(old)
