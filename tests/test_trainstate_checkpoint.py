"""Checkpoint/resume of the PoFEL train state (LLM-scale path)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.fl import pofel_trainer as pt
from repro.models.model_api import Model
from repro.models.transformer import FwdOptions

OPTS = FwdOptions(remat=False)


def test_pofel_state_checkpoint_resume(tmp_path):
    model = Model(get_config("starcoder2-3b").reduced())
    cfg = pt.PoFELTrainConfig(n_clusters=2, inner_lr=1e-2)
    state = pt.init_train_state(model, cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 500, (2, 2, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 500, (2, 2, 16)), jnp.int32)}
    lam = jnp.ones((2,))

    state, _ = pt.pofel_round(model, state, batch, lam, cfg, OPTS)
    save_checkpoint(tmp_path, int(state.round), state)

    restored = load_checkpoint(tmp_path, 1, state)
    # continuing from restored state gives bit-identical results
    s1, m1 = pt.pofel_round(model, state, batch, lam, cfg, OPTS)
    s2, m2 = pt.pofel_round(model, restored, batch, lam, cfg, OPTS)
    np.testing.assert_array_equal(np.asarray(m1.similarities),
                                  np.asarray(m2.similarities))
    for a, b in zip(jax.tree.leaves(s1.global_params),
                    jax.tree.leaves(s2.global_params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
