"""Differential backend parity for the secp256k1 point-arithmetic seam.

Every backend — ``naive`` (Jacobian double-and-add), ``windowed``
(fixed-window tables), ``batch`` (windowed + the RLC batch equation), and
``jax`` (limb-vectorized RLC kernel) — must agree with the single source
of truth, the per-message ``dverify`` predicate, on every input shape:
valid tags, forged tags, tampered recovery bits, and bare ``(r, s)``
pairs. Property-driven via the optional-hypothesis shim.

The curve layer is pinned separately: the Jacobian formulas (including
the batched-inversion window-table build) must reproduce the affine
baseline bit-for-bit — that equivalence is what makes the backend sweep
in ``benchmarks/bench_hcds.py`` a fair before/after.
"""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import crypto
from repro.core.crypto import curve

BACKENDS = list(crypto.BACKENDS)
try:                                    # the jax backend is dependency-gated
    crypto._get_ops("jax")
except Exception:                       # pragma: no cover - jax-less installs
    BACKENDS.remove("jax")

_KPS = [crypto.ECDSAKeyPair.generate(bytes([i, 0xBE])) for i in range(6)]


def _batch(n):
    items = []
    for i in range(n):
        d = crypto.sha256_digest(b"parity", bytes([i]))
        items.append((crypto.dsign(d, _KPS[i].private_key),
                      _KPS[i].public_key, d))
    return items


def _mutate(item, shape):
    """One input shape per code path verify_batch must route correctly."""
    tag, pk, d = item
    if shape == "valid":
        return item
    if shape == "bare-pair":            # legacy (r, s): singles fallback
        return ((tag.r, tag.s), pk, d)
    if shape == "flipped-v":            # defeats the equation, not dverify
        return (crypto.Signature(tag.r, tag.s, tag.v ^ 1), pk, d)
    if shape == "forged-s":
        return (crypto.Signature(tag.r, tag.s ^ 0x2, tag.v), pk, d)
    if shape == "forged-digest":
        return (tag, pk, crypto.sha256_digest(d))
    raise AssertionError(shape)


_SHAPES = ("valid", "bare-pair", "flipped-v", "forged-s", "forged-digest")
_ACCEPTED = {"valid", "bare-pair", "flipped-v"}


# ---------------------------------------------------------------------------
# dsign / dverify parity
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_dsign_dverify_agree_across_backends(seed):
    kp = crypto.ECDSAKeyPair.generate(seed.to_bytes(4, "big"))
    d = crypto.sha256_digest(seed.to_bytes(4, "big"))
    tags = {}
    for be in BACKENDS:
        with crypto.use_backend(be):
            tags[be] = crypto.dsign(d, kp.private_key)
            assert crypto.dverify(tags[be], kp.public_key, d), be
            assert not crypto.dverify(tags[be], kp.public_key,
                                      crypto.sha256_digest(d)), be
    # deterministic RFC-6979 nonces ⇒ bit-identical tags everywhere
    assert len(set(tags.values())) == 1


# ---------------------------------------------------------------------------
# verify_batch parity: accept-iff-dverify under every backend
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 4), mask=st.integers(0, 15),
       shape=st.sampled_from(_SHAPES[1:]))
def test_verify_batch_parity_across_backends(n, mask, shape):
    items = _batch(n)
    mutated = [i for i in range(n) if (mask >> i) & 1]
    for i in mutated:
        items[i] = _mutate(items[i], shape)
    with crypto.use_backend("windowed"):
        individually = [crypto.dverify(t, pk, d) for t, pk, d in items]
    expected_bad = [i for i, ok in enumerate(individually) if not ok]
    if shape in _ACCEPTED:
        assert not expected_bad
    results = {be: crypto.verify_batch(items, backend=be)
               for be in BACKENDS}
    for be, res in results.items():
        assert res.ok == all(individually), (be, shape)
        assert list(res.bad) == expected_bad, (be, shape)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(2, 4), forged=st.integers(0, 3))
def test_deduplicated_receiver_copies_parity(n, forged):
    """The round workload — every receiver re-checks every sender — yields
    identical per-copy attribution under every backend."""
    forged %= n
    items = _batch(n)
    items[forged] = _mutate(items[forged], "forged-s")
    copies = [it for it in items for _ in range(n - 1)]
    expected = tuple(range(forged * (n - 1), (forged + 1) * (n - 1)))
    for be in BACKENDS:
        res = crypto.verify_batch(copies, backend=be)
        assert not res.ok and res.bad == expected, be


def test_mixed_shapes_one_batch_all_backends():
    """Valid, bare-pair, flipped-v, and two forgery kinds in ONE batch:
    the accept set is exactly the individually-valid items everywhere."""
    items = [_mutate(it, shape)
             for it, shape in zip(_batch(len(_SHAPES)), _SHAPES)]
    expected = (3, 4)                   # the two forged shapes
    for be in BACKENDS:
        res = crypto.verify_batch(items, backend=be)
        assert res.bad == expected, be


# ---------------------------------------------------------------------------
# curve-layer pins: Jacobian == affine baseline
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_jacobian_matches_affine_scalar_mul(seed):
    k = (seed * 0x9E3779B97F4A7C15 + 1) % curve.N
    table = curve.g_table()
    affine = curve.affine_point_mul_windowed(k, table)
    assert curve.point_mul_windowed(k, table) == affine
    assert curve.point_mul_naive(k, curve.G) == affine


@settings(max_examples=8, deadline=None)
@given(a=st.integers(1, 1 << 128), b=st.integers(1, 1 << 128))
def test_jacobian_multi_scalar_matches_affine(a, b):
    pk = _KPS[0].public_key
    pairs = [(a, curve.G), (b, pk)]
    assert curve.multi_scalar(pairs) == curve.affine_multi_scalar(pairs)
    assert curve.strauss_shamir(a, curve.G, b, pk) == \
        curve.affine_multi_scalar(pairs)


def test_window_table_batched_inversion_matches_affine_build():
    """The Jacobian table build (one batched inversion for all 64×15
    entries) must produce exactly the affine baseline's table: d·16^w·Q
    via repeated affine adds."""
    q = _KPS[1].public_key
    table = curve.build_window_table(q)
    base = q
    for w in range(4):                  # spot-check the first few windows
        expect = curve.INF
        for d in range(15):
            expect = curve.affine_point_add(expect, base)
            assert table[w][d] == expect
        for _ in range(curve._WINDOW_BITS):
            base = curve.affine_point_add(base, base)
