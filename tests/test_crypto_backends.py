"""Differential backend parity for the secp256k1 point-arithmetic seam.

Every backend — ``naive`` (Jacobian double-and-add), ``windowed``
(fixed-window tables), ``batch`` (GLV + wNAF/Pippenger MSM behind the RLC
batch equation), ``glv`` (same MSM forced onto the interleaved-wNAF
engine), and ``jax`` (GLV limb-vectorized RLC kernel) — must agree with
the single source of truth, the per-message ``dverify`` predicate, on
every input shape: valid tags, forged tags, tampered recovery bits, and
bare ``(r, s)`` pairs. Property-driven via the optional-hypothesis shim.

The curve layer is pinned separately: the Jacobian formulas (including
the batched-inversion window-table build) must reproduce the affine
baseline bit-for-bit — that equivalence is what makes the backend sweep
in ``benchmarks/bench_hcds.py`` a fair before/after.
"""

import json
import random
from pathlib import Path

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import crypto
from repro.core.crypto import curve

BACKENDS = list(crypto.BACKENDS)
try:                                    # the jax backend is dependency-gated
    crypto._get_ops("jax")
except Exception:                       # pragma: no cover - jax-less installs
    BACKENDS.remove("jax")

_KPS = [crypto.ECDSAKeyPair.generate(bytes([i, 0xBE])) for i in range(6)]


def _batch(n):
    items = []
    for i in range(n):
        d = crypto.sha256_digest(b"parity", bytes([i]))
        items.append((crypto.dsign(d, _KPS[i].private_key),
                      _KPS[i].public_key, d))
    return items


def _mutate(item, shape):
    """One input shape per code path verify_batch must route correctly."""
    tag, pk, d = item
    if shape == "valid":
        return item
    if shape == "bare-pair":            # legacy (r, s): singles fallback
        return ((tag.r, tag.s), pk, d)
    if shape == "flipped-v":            # defeats the equation, not dverify
        return (crypto.Signature(tag.r, tag.s, tag.v ^ 1), pk, d)
    if shape == "forged-s":
        return (crypto.Signature(tag.r, tag.s ^ 0x2, tag.v), pk, d)
    if shape == "forged-digest":
        return (tag, pk, crypto.sha256_digest(d))
    raise AssertionError(shape)


_SHAPES = ("valid", "bare-pair", "flipped-v", "forged-s", "forged-digest")
_ACCEPTED = {"valid", "bare-pair", "flipped-v"}


# ---------------------------------------------------------------------------
# dsign / dverify parity
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_dsign_dverify_agree_across_backends(seed):
    kp = crypto.ECDSAKeyPair.generate(seed.to_bytes(4, "big"))
    d = crypto.sha256_digest(seed.to_bytes(4, "big"))
    tags = {}
    for be in BACKENDS:
        with crypto.use_backend(be):
            tags[be] = crypto.dsign(d, kp.private_key)
            assert crypto.dverify(tags[be], kp.public_key, d), be
            assert not crypto.dverify(tags[be], kp.public_key,
                                      crypto.sha256_digest(d)), be
    # deterministic RFC-6979 nonces ⇒ bit-identical tags everywhere
    assert len(set(tags.values())) == 1


# ---------------------------------------------------------------------------
# verify_batch parity: accept-iff-dverify under every backend
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 4), mask=st.integers(0, 15),
       shape=st.sampled_from(_SHAPES[1:]))
def test_verify_batch_parity_across_backends(n, mask, shape):
    items = _batch(n)
    mutated = [i for i in range(n) if (mask >> i) & 1]
    for i in mutated:
        items[i] = _mutate(items[i], shape)
    with crypto.use_backend("windowed"):
        individually = [crypto.dverify(t, pk, d) for t, pk, d in items]
    expected_bad = [i for i, ok in enumerate(individually) if not ok]
    if shape in _ACCEPTED:
        assert not expected_bad
    results = {be: crypto.verify_batch(items, backend=be)
               for be in BACKENDS}
    for be, res in results.items():
        assert res.ok == all(individually), (be, shape)
        assert list(res.bad) == expected_bad, (be, shape)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(2, 4), forged=st.integers(0, 3))
def test_deduplicated_receiver_copies_parity(n, forged):
    """The round workload — every receiver re-checks every sender — yields
    identical per-copy attribution under every backend."""
    forged %= n
    items = _batch(n)
    items[forged] = _mutate(items[forged], "forged-s")
    copies = [it for it in items for _ in range(n - 1)]
    expected = tuple(range(forged * (n - 1), (forged + 1) * (n - 1)))
    for be in BACKENDS:
        res = crypto.verify_batch(copies, backend=be)
        assert not res.ok and res.bad == expected, be


def test_mixed_shapes_one_batch_all_backends():
    """Valid, bare-pair, flipped-v, and two forgery kinds in ONE batch:
    the accept set is exactly the individually-valid items everywhere."""
    items = [_mutate(it, shape)
             for it, shape in zip(_batch(len(_SHAPES)), _SHAPES)]
    expected = (3, 4)                   # the two forged shapes
    for be in BACKENDS:
        res = crypto.verify_batch(items, backend=be)
        assert res.bad == expected, be


# ---------------------------------------------------------------------------
# curve-layer pins: Jacobian == affine baseline
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_jacobian_matches_affine_scalar_mul(seed):
    k = (seed * 0x9E3779B97F4A7C15 + 1) % curve.N
    table = curve.g_table()
    affine = curve.affine_point_mul_windowed(k, table)
    assert curve.point_mul_windowed(k, table) == affine
    assert curve.point_mul_naive(k, curve.G) == affine


@settings(max_examples=8, deadline=None)
@given(a=st.integers(1, 1 << 128), b=st.integers(1, 1 << 128))
def test_jacobian_multi_scalar_matches_affine(a, b):
    pk = _KPS[0].public_key
    pairs = [(a, curve.G), (b, pk)]
    assert curve.multi_scalar(pairs) == curve.affine_multi_scalar(pairs)
    assert curve.strauss_shamir(a, curve.G, b, pk) == \
        curve.affine_multi_scalar(pairs)


def test_window_table_batched_inversion_matches_affine_build():
    """The Jacobian table build (one batched inversion for all 64×15
    entries) must produce exactly the affine baseline's table: d·16^w·Q
    via repeated affine adds."""
    q = _KPS[1].public_key
    table = curve.build_window_table(q)
    base = q
    for w in range(4):                  # spot-check the first few windows
        expect = curve.INF
        for d in range(15):
            expect = curve.affine_point_add(expect, base)
            assert table[w][d] == expect
        for _ in range(curve._WINDOW_BITS):
            base = curve.affine_point_add(base, base)


# ---------------------------------------------------------------------------
# GLV decomposition + endomorphism
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1 << 62))
def test_glv_decompose_recomposes_with_short_halves(seed):
    """k₁ + k₂·λ ≡ k (mod n) and both halves fit in 129 signed bits —
    the property that lets the ladders run half-length."""
    k = (seed * 0x9E3779B97F4A7C15 + seed + 1) % curve.N
    k1, k2 = curve.glv_decompose(k)
    assert (k1 + k2 * curve.GLV_LAMBDA) % curve.N == k % curve.N
    assert abs(k1) < 1 << 129 and abs(k2) < 1 << 129


def test_glv_decompose_edge_scalars():
    for k in (0, 1, 2, curve.N - 1, curve.N // 2, curve.GLV_LAMBDA,
              curve.N + 7):
        k1, k2 = curve.glv_decompose(k)
        assert (k1 + k2 * curve.GLV_LAMBDA) % curve.N == k % curve.N, k
        assert abs(k1) < 1 << 129 and abs(k2) < 1 << 129, k


def test_endomorphism_is_lambda_mul():
    """φ(P) = (β·x, y) must equal λ·P — one field mul standing in for a
    whole scalar multiplication."""
    pk = _KPS[0].public_key
    assert curve.endo(pk) == curve.point_mul_naive(curve.GLV_LAMBDA, pk)
    assert curve.endo(curve.INF) == curve.INF


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(1, 1 << 62))
def test_wnaf_digits_reconstruct(seed):
    """Sparse wNAF invariants: Σ d·2^pos == k, digits odd and in
    (−2^(w−1), 2^(w−1))."""
    k = (seed * 0xD1B54A32D192ED03 + 1) % curve.N or 1
    for w in (curve._FRESH_W, curve._MSM_W):
        digits = curve.wnaf_digits(k, w)
        assert sum(d << pos for pos, d in digits) == k
        assert all(d & 1 and abs(d) < 1 << (w - 1) for _, d in digits)


# ---------------------------------------------------------------------------
# MSM engines vs the naive reference
# ---------------------------------------------------------------------------

def _msm_cases():
    pk0, pk1 = _KPS[0].public_key, _KPS[1].public_key
    neg1 = (pk1[0], (-pk1[1]) % crypto._P)
    big = curve.N - 2
    return [
        [],
        [(0, curve.G)],                              # k = 0 drops out
        [(5, curve.INF)],                            # P = ∞ drops out
        [(1, curve.G)],
        [(big, pk0), (3, pk0), (3, pk0)],            # duplicate points
        [(123456789, curve.G), (big, pk0), (0, pk1), (7, curve.INF)],
        [(curve.N - 1, pk0), (curve.N + 5, pk1)],    # k ≥ N reduces
        [(big, pk1), (big, neg1)],                   # pair cancels to ∞
    ]


def test_msm_engines_match_naive_multi_scalar():
    for pairs in _msm_cases():
        ref = curve.multi_scalar(pairs)
        assert curve.msm(fresh_pairs=pairs, engine="wnaf") == ref, pairs
        assert curve.msm(fresh_pairs=pairs, engine="pippenger") == ref, pairs
        assert curve.msm(base_pairs=pairs) == ref, pairs


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_msm_random_matches_naive(seed):
    rng = random.Random(seed)
    points = [curve.G, _KPS[0].public_key, _KPS[1].public_key,
              curve.endo(_KPS[2].public_key)]
    pairs = [(rng.randrange(0, curve.N), p) for p in points]
    ref = curve.multi_scalar(pairs)
    assert curve.msm(fresh_pairs=pairs, engine="pippenger") == ref
    assert curve.msm(fresh_pairs=pairs, engine="wnaf") == ref
    assert curve.msm(base_pairs=pairs) == ref
    split = len(pairs) // 2
    assert curve.msm(base_pairs=pairs[:split],
                     fresh_pairs=pairs[split:]) == ref


def test_msm_rejects_unknown_engine():
    with pytest.raises(ValueError):
        curve.msm_jc(fresh_pairs=[(1, curve.G)], engine="strauss")


# ---------------------------------------------------------------------------
# uniform-schedule fixed-base ladder
# ---------------------------------------------------------------------------

def test_ct_base_mul_matches_naive_edges():
    for k in (0, 1, 2, 3, curve.N - 1, curve.N // 2, (1 << 255) % curve.N):
        assert curve.point_mul_base_ct(k) == \
            curve.point_mul_naive(k, curve.G), k


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1 << 62))
def test_ct_base_mul_matches_naive_random(seed):
    k = (seed * 0xA0761D6478BD642F + seed) % curve.N
    assert curve.point_mul_base_ct(k) == curve.point_mul_naive(k, curve.G)


# ---------------------------------------------------------------------------
# cache bounds: the per-key tables must not grow without bound
# ---------------------------------------------------------------------------

def _distinct_points(n):
    return [curve.point_mul_naive(i + 2, curve.G) for i in range(n)]


def test_msm_table_cache_is_bounded_lru(monkeypatch):
    monkeypatch.setattr(curve, "_MSM_CACHE_MAX", 4)
    curve._MSM_TABLES.clear()
    pts = _distinct_points(6)
    for p in pts:
        curve.msm_table(p)
    assert len(curve._MSM_TABLES) == 4
    assert pts[0] not in curve._MSM_TABLES      # oldest evicted
    assert pts[-1] in curve._MSM_TABLES
    curve.msm_table(pts[2])                     # touch → most recent
    curve.msm_table(_distinct_points(7)[-1])    # insert → evicts pts[3]
    assert pts[2] in curve._MSM_TABLES
    assert pts[3] not in curve._MSM_TABLES
    curve._MSM_TABLES.clear()


def test_pk_table_cache_is_bounded_lru(monkeypatch):
    monkeypatch.setattr(curve, "_PK_CACHE_MAX", 4)
    curve._PK_TABLES.clear()
    pts = _distinct_points(6)
    for p in pts:
        curve.pk_table(p)
    assert len(curve._PK_TABLES) == 4
    assert pts[0] not in curve._PK_TABLES
    curve.pk_table(pts[2])                      # touch → survives the next
    curve.pk_table(_distinct_points(7)[-1])     # insert (evicts pts[3])
    assert pts[2] in curve._PK_TABLES
    assert pts[3] not in curve._PK_TABLES
    curve._PK_TABLES.clear()


def test_g_msm_table_not_in_lru():
    """The base-point table is pinned module-global — it must never
    occupy (or be evicted from) the bounded public-key LRU."""
    t = curve.msm_table(curve.G)
    assert t is curve.g_msm_table()
    assert curve.G not in curve._MSM_TABLES


# ---------------------------------------------------------------------------
# Pippenger engine drives the full verify path
# ---------------------------------------------------------------------------

def test_bisection_parity_with_pippenger_engine(monkeypatch):
    """Force the bucket engine under the batch backend's RLC equation:
    forgery attribution must be identical to the default engine."""
    from repro.core.crypto.backends.python import BatchOps
    monkeypatch.setattr(BatchOps, "msm_engine", "pippenger")
    items = _batch(6)
    items[2] = _mutate(items[2], "forged-s")
    items[5] = _mutate(items[5], "forged-digest")
    res = crypto.verify_batch(items, backend="batch")
    assert not res.ok and res.bad == (2, 5)
    assert crypto.verify_batch(_batch(5), backend="batch").ok


# ---------------------------------------------------------------------------
# benchmark headline pins (committed BENCH_crypto.json)
# ---------------------------------------------------------------------------

def test_bench_crypto_headline_pins():
    """The committed sweep must carry this PR's acceptance numbers:
    batch ≥2× over the PR-5 batch reconstruction at N=32, and a jax AOT
    warm start (fresh process, serialized-kernel hit) under 1 s."""
    path = (Path(__file__).resolve().parents[1]
            / "benchmarks" / "BENCH_crypto.json")
    data = json.loads(path.read_text())
    t = data["target"]
    assert t["measured_at_N32"] >= t["min_batch_speedup_vs_pr5_at_N32"]
    assert data["point_backends"]["N32"]["batch_speedup_vs_pr5"] >= 2.0
    warm = t["measured_jax_warm_start_s_at_l16"]
    assert warm is not None and warm < t["max_jax_warm_start_s"]
    assert data["calibration"]["chosen"] == data["default_backend"]
