"""Stackelberg incentive mechanism (paper §5, Thms 5.1-5.2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.incentive import (NodeParams, PublisherParams, best_response,
                                  best_response_iteration, node_utility,
                                  optimal_delta, publisher_utility,
                                  stackelberg_equilibrium)


def _nodes(n=5, gamma=0.01, mu=5.0):
    return NodeParams(jnp.full((n,), gamma), jnp.full((n,), mu))


def test_best_response_is_stationary_point():
    """Thm 5.1: ∂U_i/∂f_i = 0 at the best response."""
    delta, f_rest, gamma, mu = 5000.0, 1000.0, 0.01, 5.0
    f_star = float(best_response(jnp.asarray(f_rest), jnp.asarray(delta),
                                 jnp.asarray(gamma), jnp.asarray(mu)))
    grad = (delta * f_rest / (f_rest + f_star) ** 2 - 2 * gamma * mu * f_star)
    assert abs(grad) < 1e-3
    # and it is a maximum: utility lower on both sides
    u = lambda f: float(node_utility(jnp.asarray(f), jnp.asarray(f_rest),
                                     jnp.asarray(delta), jnp.asarray(gamma),
                                     jnp.asarray(mu)))
    assert u(f_star) >= u(f_star * 0.9) and u(f_star) >= u(f_star * 1.1)


def test_nash_equilibrium_symmetric():
    """Symmetric nodes reach a symmetric Nash equilibrium."""
    nodes = _nodes(4)
    f = best_response_iteration(jnp.asarray(3000.0), nodes,
                                jnp.full((4,), 1.0))
    f = np.asarray(f)
    assert np.allclose(f, f[0], rtol=1e-3)
    assert np.all(f > 0)


def test_publisher_optimum_matches_theorem():
    """Thm 5.2: δ* = F* φ / λ, and it maximizes U_tp."""
    p = PublisherParams(B=500.0, lam=1.0, phi=5.0)
    F = jnp.asarray(1000.0)
    d_star = float(optimal_delta(F, p))
    assert d_star == pytest.approx(5000.0)
    u_star = float(publisher_utility(jnp.asarray(d_star), F, p))
    assert u_star == pytest.approx(p.B)          # parabola apex
    for d in (d_star * 0.8, d_star * 1.2):
        assert float(publisher_utility(jnp.asarray(d), F, p)) < u_star


def test_full_equilibrium_consistency():
    """Backward induction: at (δ*, f*), the publisher's δ equals δ*(F*) and
    node utilities are non-negative (participation constraint)."""
    nodes = _nodes(5)
    sol = stackelberg_equilibrium(nodes)
    assert float(sol.delta_star) == pytest.approx(
        float(sol.F_star) * 5.0 / 1.0, rel=1e-3)
    assert np.all(np.asarray(sol.node_utilities) >= 0)
    assert float(sol.publisher_utility) == pytest.approx(500.0, rel=1e-3)


def test_heterogeneous_costs_lower_investment():
    """Nodes with higher energy cost γ_i invest fewer CPU cycles."""
    nodes = NodeParams(jnp.asarray([0.005, 0.01, 0.02, 0.04]),
                       jnp.full((4,), 5.0))
    sol = stackelberg_equilibrium(nodes)
    f = np.asarray(sol.f_star)
    assert np.all(np.diff(f) < 0)
