"""repro.sim — event-driven BHFL simulator: bus determinism, scenario
registry, and the fault-scenario acceptance pins (liveness under a
Byzantine third, leader re-election on crash, ledger convergence after a
healed partition)."""

import math

import numpy as np
import pytest

from repro import api, sim
from repro.fl.hfl_runtime import BHFLConfig
from repro.sim.network import (ChurnSpec, LinkSpec, NetworkConfig,
                               PartitionSpec, SimNetwork)


# ---------------------------------------------------------------------------
# message bus
# ---------------------------------------------------------------------------

def _deliveries(seed, drop=0.3):
    net = SimNetwork(4, NetworkConfig(link=LinkSpec(drop_rate=drop)),
                     seed=seed)
    out = []
    for _ in range(5):
        d = net.exchange("commit", {i: f"m{i}" for i in range(4)})
        out.append({r: sorted(s) for r, s in d.items()})
    return out


def test_bus_is_deterministic_per_seed():
    assert _deliveries(7) == _deliveries(7)


def test_partition_blocks_cross_group_traffic():
    cfg = NetworkConfig(partitions=(
        PartitionSpec(groups=((0, 1), (2, 3)), start_round=0, end_round=2),))
    net = SimNetwork(4, cfg, seed=0)
    d = net.exchange("commit", {i: i for i in range(4)})
    for recv, senders in d.items():
        for s in senders:
            assert {recv, s} <= {0, 1} or {recv, s} <= {2, 3}
    net.set_round(2)    # healed
    d = net.exchange("commit", {i: i for i in range(4)})
    assert all(len(s) == 3 for s in d.values())


def test_churn_removes_node_from_alive_set():
    cfg = NetworkConfig(churn=(ChurnSpec(node=2, down_from=1, down_until=3),))
    net = SimNetwork(4, cfg, seed=0)
    assert net.alive() == {0, 1, 2, 3}
    net.set_round(1)
    assert net.alive() == {0, 1, 3}
    net.set_round(3)
    assert net.alive() == {0, 1, 2, 3}


def test_partition_must_cover_all_nodes():
    with pytest.raises(ValueError, match="cover every node"):
        SimNetwork(4, NetworkConfig(partitions=(
            PartitionSpec(groups=((0, 1),), start_round=0, end_round=1),)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_the_core_scenarios():
    names = sim.list_scenarios()
    for required in ("lossy_wan", "partitioned_edges", "byzantine_third",
                     "leader_crash", "plagiarist"):
        assert required in names


def test_unknown_scenario_raises_with_available_names():
    with pytest.raises(KeyError, match="byzantine_third"):
        sim.get_scenario("no-such-scenario")


def test_default_quorum_is_two_thirds():
    env = sim.build_env(sim.get_scenario("ideal"), seed=0)
    assert env.quorum == math.ceil(2 * 6 / 3)


# ---------------------------------------------------------------------------
# acceptance pins (ISSUE 3)
# ---------------------------------------------------------------------------

def _report_fingerprint(r):
    """The determinism-relevant view of a ScenarioReport."""
    return (r.completed_rounds, r.aborted_rounds, r.safety_violations,
            r.final_heights, r.final_heads,
            [(x.round, x.leader, x.aborted, x.reelections, x.heads)
             for x in r.rounds])


def test_byzantine_third_deterministic_live_and_safe():
    """api.run_bhfl(scenario="byzantine_third", seed=0): deterministic,
    completes all rounds, zero safety violations, every honest node ends
    at identical chain height/head hash."""
    runs = [api.run_bhfl(scenario="byzantine_third", seed=0)
            for _ in range(2)]
    reports = [run.scenario_report for run in runs]
    assert _report_fingerprint(reports[0]) == _report_fingerprint(reports[1])
    r = reports[0]
    assert r.liveness and r.completed_rounds == r.rounds_requested
    assert r.safety_violations == 0
    assert len(set(r.final_heights.values())) == 1
    assert len(set(r.final_heads.values())) == 1
    assert runs[0].chain_valid
    # BTSV held: bribery never displaced the honest similarity argmax
    assert r.argmax_leader_rate == 1.0


def test_leader_crash_reelects_and_stays_live():
    r = sim.run_scenario("leader_crash", seed=0)
    assert r.liveness and r.safety_violations == 0
    assert r.reelections >= 2          # rounds 1 and 3 crash their leader
    assert r.converged
    crashed = [x for x in r.rounds if x.round in (1, 3)]
    assert all(x.reelections >= 1 and not x.aborted for x in crashed)


def test_partitioned_edges_diverges_then_converges():
    r = sim.run_scenario("partitioned_edges", seed=0)
    assert r.liveness and r.safety_violations == 0
    assert r.rounds_to_recover >= 1    # minority fell behind mid-run
    by_round = {x.round: x for x in r.rounds}
    assert by_round[2].diverged        # partition active
    assert not by_round[r.rounds_requested - 1].diverged   # healed in-run
    assert r.converged
    assert len(set(r.final_heads.values())) == 1


def test_lossy_wan_converges_despite_drops():
    r = sim.run_scenario("lossy_wan", seed=0)
    assert r.liveness and r.safety_violations == 0 and r.converged
    dropped = sum(s.get("dropped", 0) for s in r.net_stats.values())
    assert dropped > 0                 # the faults actually fired


def test_forged_envelopes_attributed_and_rejected():
    """Message-layer forgery (signatures by a key the node does not own):
    batch verification bisects and attributes exactly the forger's commit
    and vote envelopes; honest traffic in the same batches is untouched
    and the run stays live, safe, and converged."""
    r = sim.run_scenario("forged_envelopes", seed=0)
    assert r.liveness and r.safety_violations == 0 and r.converged
    assert r.rejected_envelopes == 2 * r.rounds_requested   # commit + vote
    for x in r.rounds:
        assert x.rejected.get(5) == "forged-envelope"
        assert 5 not in (x.available or [])
    blamed = {e["node"] for e in r.events
              if e["event"] == "envelope_rejected"}
    assert blamed == {5}                  # no honest node was ever accused
    assert r.honest_leader_rate == 1.0


def test_scenario_object_and_round_override():
    sc = sim.get_scenario("ideal")
    run = api.run_bhfl(scenario=sc, seed=1, rounds=2)
    assert run.scenario_report.rounds_requested == 2
    assert run.scenario_report.liveness
    assert run.chain_height == 2


def test_custom_faults_env():
    """faults= takes a prebuilt SimEnv for ad-hoc injection."""
    from repro.data.synthetic import make_mnist_like
    env = sim.build_env(sim.get_scenario("ideal"), seed=3)
    run = api.run_bhfl(faults=env, n_nodes=6, clients_per_node=2,
                       fel_iterations=1, rounds=2,
                       data=make_mnist_like(n_train=256, n_test=64, seed=3))
    assert run.scenario_report.scenario == "custom"
    assert run.scenario_report.liveness


def test_scenario_and_faults_are_mutually_exclusive():
    env = sim.build_env(sim.get_scenario("ideal"), seed=0)
    with pytest.raises(ValueError, match="not both"):
        api.run_bhfl(scenario="ideal", faults=env)


def test_faults_env_size_must_match_run():
    env = sim.build_env(sim.get_scenario("ideal"), n_nodes=8, seed=0)
    with pytest.raises(ValueError, match="simulates 8 nodes"):
        api.run_bhfl(faults=env, n_nodes=6, rounds=1)


def test_permanently_crashed_node_is_not_resurrected():
    """The final sync must not force-feed blocks to a node that is still
    down — the report shows its stale chain instead of fake convergence."""
    sc = sim.Scenario(
        name="perma_crash_adhoc", description="node 5 dies after round 0",
        rounds=3,
        net=sim.NetworkConfig(churn=(sim.ChurnSpec(node=5, down_from=1),)))
    r = api.run_bhfl(scenario=sc, seed=0).scenario_report
    assert r.liveness                      # 5 live nodes >= quorum of 4
    assert r.final_heights[5] == 1         # died holding only round 0
    assert not r.converged                 # truthfully not converged


def test_all_honest_down_aborts_round_instead_of_crashing():
    """Plagiarists with no live honest victim is a liveness gap, not an
    IndexError."""
    sc = sim.Scenario(
        name="dead_honest_adhoc", description="honest nodes all crash",
        rounds=2, n_nodes=3,
        adversaries=(sim.Plagiarist(2),),
        net=sim.NetworkConfig(churn=(sim.ChurnSpec(node=0, down_from=1),
                                     sim.ChurnSpec(node=1, down_from=1))))
    r = api.run_bhfl(scenario=sc, seed=0).scenario_report
    assert not r.rounds[0].aborted
    assert r.rounds[1].aborted             # no honest model to plagiarize
    assert not r.liveness


def test_safety_violation_survives_reconvergence():
    """A fork two honest nodes once held is a safety violation even after
    fork-choice/catch-up sync erased the losing chain."""
    from types import SimpleNamespace
    from repro.blockchain.block import GENESIS_HASH, Block
    from repro.blockchain.ledger import Ledger
    from repro.core import crypto

    kp = crypto.ECDSAKeyPair.generate(b"leader")

    def chain(salt):
        led = Ledger(0)
        led.append(Block(index=0, round=0, leader_id=0,
                         prev_hash=GENESIS_HASH, model_digests={0: salt},
                         global_model_digest="gw", votes={0: 0},
                         vote_weights={0: 1.0}, advotes={0: 1.0}).signed(kp),
                   leader_pk=kp.public_key)
        return led

    led_a, led_b = chain("aa"), chain("bb")       # conflicting height 0
    led_b.node_id = 1
    env = sim.build_env(sim.get_scenario("ideal"), n_nodes=2, seed=0)
    env.bind(SimpleNamespace(ledgers=[led_a, led_b],
                             public_keys={0: kp.public_key}))
    env.end_round(0, SimpleNamespace(consensus=None, leader_id=-1),
                  aborted=True)
    report = env.finalize("forked", seed=0, rounds_requested=1)
    assert report.safety_violations == 1          # the fork was witnessed
    assert report.converged                       # ...and later healed


# ---------------------------------------------------------------------------
# run_bhfl keyword hygiene (typo'd scenario=/engine= must not run silently)
# ---------------------------------------------------------------------------

def test_unknown_kwarg_raises_with_suggestion():
    with pytest.raises(TypeError, match="did you mean 'scenario'"):
        api.run_bhfl(scneario="byzantine_third")
    with pytest.raises(TypeError, match="did you mean 'engine'"):
        api.run_bhfl(enigne="batched")


def test_config_overrides_forwarded_by_name():
    run = api.run_bhfl(scenario="ideal", seed=0, rounds=1, lr=5e-3,
                       batch_size=16)
    assert run.runtime.cfg.lr == 5e-3
    assert run.runtime.cfg.batch_size == 16


def test_config_overrides_conflict_with_explicit_cfg():
    with pytest.raises(ValueError, match="set them on the BHFLConfig"):
        api.run_bhfl(cfg=BHFLConfig(), lr=5e-3)


# ---------------------------------------------------------------------------
# plagiarism attribution under jittered delivery (the §4.1 tie-break)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plag,seed", [(3, 0), (3, 3), (0, 0)])
def test_plagiarist_blamed_consistently_regardless_of_arrival_order(plag,
                                                                    seed):
    """With no reveal lag the copy's reveal races the victim's at every
    receiver (per-receiver jittered arrival order). Attribution must come
    from commitment precedence — the chain-inclusion order of the commit
    stage, in which the copy necessarily trails (it could only be
    constructed after observing the victim's bytes): every honest node
    ends the round holding the victim's model and blaming the (actually
    guilty) plagiarist — even one with a LOWER id than its victim — and
    the honest victim never lands in the round's rejections."""
    from repro.sim.adversary import Plagiarist
    victim = 0 if plag != 0 else 1  # Plagiarist copies the first honest model
    sc = sim.Scenario(
        name=f"plagiarist_no_lag_{plag}_{seed}",
        description="plagiarist whose reveal races the victim's",
        rounds=3, n_nodes=4,
        net=NetworkConfig(link=LinkSpec(base_latency=1.0, jitter=8.0)),
        adversaries=(Plagiarist(plag, reveal_lag=0.0),))
    run = api.run_bhfl(scenario=sc, seed=seed)
    report = run.scenario_report
    assert report.liveness and report.safety_violations == 0
    for r in report.rounds:
        assert r.rejected.get(plag) == "plagiarized-model"
        assert victim not in r.rejected
        assert victim in (r.available or [])
        assert plag not in (r.available or [])
        assert r.leader != plag
    # every honest receiver converged on the same accepted set: the
    # victim's reveal in, the byte-identical copy out
    nodes = run.runtime.consensus.hcds_nodes
    last_round = report.rounds[-1].round
    for i in run.runtime.env.honest_ids():
        accepted = nodes[i].accepted_models(last_round)
        assert victim in accepted and plag not in accepted, i
