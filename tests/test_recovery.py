"""repro.core.recovery: durable WAL, crash/replay, ledger snapshots (ISSUE 7).

The WAL's contract: appending the statement you already logged is
idempotent; appending a *conflicting* statement for an already-logged
(kind, round) raises WALConflict. Replay is idempotent (restart twice ≡
restart once), so a node that reboots mid-round re-broadcasts exactly
what it signed before the crash.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import crypto
from repro.core.consensus import PoFELConsensus
from repro.core.hcds import HCDSNode
from repro.core.recovery import (LedgerSnapshot, NodeWAL, WALConflict,
                                 load_snapshot, rejoin_ledger, replay_wal,
                                 restore_ledger, save_snapshot,
                                 snapshot_ledger, wipe_volatile)


# ---------------------------------------------------------------------------
# NodeWAL semantics
# ---------------------------------------------------------------------------

def test_wal_append_is_idempotent_and_refuses_conflicts():
    wal = NodeWAL(0)
    rec = wal.append("vote", 3, "5")
    assert wal.append("vote", 3, "5") is rec          # identical: idempotent
    assert len(wal) == 1
    with pytest.raises(WALConflict):
        wal.append("vote", 3, "4")                    # conflicting: refused
    assert wal.append("vote", 4, "4").round == 4      # other rounds fine
    assert wal.lookup("vote", 3).digest == "5"


def test_wal_file_backing_survives_reopen(tmp_path):
    path = tmp_path / "node0.wal"
    wal = NodeWAL(0, path=path)
    wal.log_vote(0, 2)
    wal.log_block(0, "ab" * 32)
    # a NEW process opening the same file sees the same records and
    # enforces the same conflicts
    reopened = NodeWAL(0, path=path)
    assert [(r.kind, r.round, r.digest) for r in reopened.records()] == \
           [(r.kind, r.round, r.digest) for r in wal.records()]
    with pytest.raises(WALConflict):
        reopened.log_vote(0, 3)
    assert reopened.log_vote(0, 2).digest == "2"      # re-log: idempotent


def test_wal_commit_record_conflict_on_different_model():
    wal = NodeWAL(7)
    node = HCDSNode(7, wal=wal)
    c = node.commit(None, round=0, model_bytes=b"model-A")
    # same round, same model: the WAL re-issues the identical statement
    again = node.commit(None, round=0, model_bytes=b"model-A")
    assert again == c
    # same round, DIFFERENT model: the double-sign the WAL must refuse
    with pytest.raises(WALConflict):
        node.commit(None, round=0, model_bytes=b"model-B")


# ---------------------------------------------------------------------------
# Crash + replay
# ---------------------------------------------------------------------------

def _committed_node(rounds=3):
    wal = NodeWAL(1)
    node = HCDSNode(1, wal=wal)
    commits = {k: node.commit(None, round=k,
                              model_bytes=b"model-%d" % k)
               for k in range(rounds)}
    return node, wal, commits


def test_replay_reissues_identical_commitments():
    node, wal, commits = _committed_node()
    wipe_volatile(node)                      # the crash
    assert node._own == {} and node._commits == {}
    applied = replay_wal(node, wal)          # the restart
    assert applied == len(commits)
    for k, c in commits.items():
        assert node._commits[k][1] == c      # byte-identical statement
        r = node.reveal(k)                   # reveal still binds
        assert crypto.sha256_digest(r.nonce, r.model_bytes) == c.digest


def test_replay_is_idempotent_restart_twice_equals_once():
    node, wal, _ = _committed_node()

    def state(n):
        return (dict(n._own),
                {k: dict(v) for k, v in n._commits.items()},
                {k: dict(v) for k, v in n._commit_order.items()})

    wipe_volatile(node)
    replay_wal(node, wal)
    once = state(node)
    wipe_volatile(node)
    replay_wal(node, wal)
    replay_wal(node, wal)                    # restart twice
    assert state(node) == once


@settings(max_examples=12, deadline=None)
@given(rounds=st.integers(min_value=1, max_value=5),
       crashes=st.integers(min_value=1, max_value=3))
def test_replay_idempotence_property(rounds, crashes):
    """Property form: any number of crash/replay cycles leaves the node in
    the single-replay state, and every re-commit is the logged one."""
    node, wal, commits = _committed_node(rounds=rounds)
    for _ in range(crashes):
        wipe_volatile(node)
        replay_wal(node, wal)
    for k, c in commits.items():
        # a post-restart commit() re-issues the logged statement
        assert node.commit(None, round=k,
                           model_bytes=b"model-%d" % k) == c
    # exactly one commit record per round, no duplicates from the cycles
    assert sum(1 for r in wal.records() if r.kind == "commit") == rounds
    assert len(wal) == rounds


def test_consensus_nodes_carry_wals_by_default():
    cons = PoFELConsensus(n_nodes=3)
    assert set(cons.wals) == {0, 1, 2}
    assert all(cons.hcds_nodes[i].wal is cons.wals[i] for i in range(3))


# ---------------------------------------------------------------------------
# Ledger snapshot / restore / rejoin
# ---------------------------------------------------------------------------

def _mini_chain(n_nodes=3, rounds=2):
    """A tiny real chain via the ideal-mode consensus driver."""
    cons = PoFELConsensus(n_nodes=n_nodes)
    rng = np.random.default_rng(0)
    for _ in range(rounds):
        models = [{"w": rng.normal(size=4).astype(np.float32)}
                  for _ in range(n_nodes)]
        cons.run_round(models, data_sizes=[1.0] * n_nodes)
    return cons


def test_ledger_snapshot_roundtrip_and_tamper_detection():
    cons = _mini_chain()
    led = cons.ledgers[0]
    snap = snapshot_ledger(led)
    restored = restore_ledger(snap, cons.public_keys)
    assert restored.height == led.height
    assert restored.head_hash == led.head_hash
    # a tampered payload fails the checkpoint-style integrity digest
    bad = LedgerSnapshot(snap.node_id, snap.height, snap.head, snap.digest,
                         snap.payload.replace("leader_id", "leader_1d"))
    with pytest.raises(Exception):
        restore_ledger(bad, cons.public_keys)


def test_snapshot_directory_roundtrip(tmp_path):
    cons = _mini_chain()
    led = cons.ledgers[1]
    model = {"w": np.arange(4, dtype=np.float32)}
    save_snapshot(tmp_path, led, model_tree=model)
    restored, restored_model = load_snapshot(
        tmp_path, node_id=1, public_keys=cons.public_keys,
        model_template=model)
    assert restored.head_hash == led.head_hash
    np.testing.assert_array_equal(restored_model["w"], model["w"])


def test_rejoin_ledger_adopts_best_reachable_chain():
    cons = _mini_chain(rounds=3)
    stale = snapshot_ledger(cons.ledgers[0])
    behind = restore_ledger(stale, cons.public_keys)
    behind.blocks = behind.blocks[:1]        # the node missed two rounds
    adopted = rejoin_ledger(behind, [cons.ledgers[1], cons.ledgers[2]],
                            cons.public_keys)
    assert adopted == 2
    assert behind.head_hash == cons.ledgers[1].head_hash
    # already caught up: nothing to adopt, and no peers is a no-op
    assert rejoin_ledger(behind, [cons.ledgers[1]], cons.public_keys) == 0
    assert rejoin_ledger(behind, [], cons.public_keys) == 0
