"""HCDS crypto primitives: SHA-256 commitment + secp256k1 ECDSA."""

import hashlib

import pytest

from repro.core import crypto


def test_sha256_matches_hashlib():
    assert crypto.sha256_digest(b"ab", b"cd") == hashlib.sha256(b"abcd").digest()


def test_keypair_deterministic_from_seed():
    k1 = crypto.ECDSAKeyPair.generate(b"seed")
    k2 = crypto.ECDSAKeyPair.generate(b"seed")
    assert k1 == k2
    k3 = crypto.ECDSAKeyPair.generate(b"other")
    assert k1.private_key != k3.private_key


def test_sign_verify_roundtrip():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    d = crypto.sha256_digest(b"model bytes")
    tag = crypto.dsign(d, kp.private_key)
    assert crypto.dverify(tag, kp.public_key, d)


def test_verify_rejects_wrong_digest():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    tag = crypto.dsign(crypto.sha256_digest(b"m"), kp.private_key)
    assert not crypto.dverify(tag, kp.public_key, crypto.sha256_digest(b"m2"))


def test_verify_rejects_wrong_key():
    kp0 = crypto.ECDSAKeyPair.generate(b"node-0")
    kp1 = crypto.ECDSAKeyPair.generate(b"node-1")
    d = crypto.sha256_digest(b"m")
    tag = crypto.dsign(d, kp0.private_key)
    assert not crypto.dverify(tag, kp1.public_key, d)


def test_verify_rejects_malformed_signature():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    d = crypto.sha256_digest(b"m")
    assert not crypto.dverify((0, 1), kp.public_key, d)
    assert not crypto.dverify((1, 0), kp.public_key, d)


def test_signature_deterministic_rfc6979():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    d = crypto.sha256_digest(b"m")
    assert crypto.dsign(d, kp.private_key) == crypto.dsign(d, kp.private_key)


def test_public_key_on_curve():
    kp = crypto.ECDSAKeyPair.generate(b"x")
    x, y = kp.public_key
    p = crypto._P
    assert (y * y - (x * x * x + 7)) % p == 0


def test_dsign_retry_signs_the_original_digest(monkeypatch):
    """The r==0/s==0 retry must re-randomize the RFC-6979 nonce, NOT the
    message: a retried signature still verifies against the caller's
    digest. Forced here by handing dsign k=1 for a private key crafted so
    that s = z + r·priv ≡ 0 (mod n)."""
    digest = crypto.sha256_digest(b"retry me")
    z = crypto._bits2int(digest)
    r = crypto._GX % crypto._N                  # k=1 → R = G
    priv = (crypto._N - z) * crypto._inv_mod(r, crypto._N) % crypto._N
    pub = crypto._point_mul(priv, (crypto._GX, crypto._GY))

    calls = []
    real = crypto._rfc6979_k

    def forced(msg_hash, key, extra=b""):
        calls.append((msg_hash, extra))
        if len(calls) == 1:
            return 1                            # s == 0 → must retry
        return real(msg_hash, key, extra=extra)

    monkeypatch.setattr(crypto, "_rfc6979_k", forced)
    tag = crypto.dsign(digest, priv)
    assert len(calls) == 2
    # the retry re-seeded the DRBG instead of mutating the message
    assert [h for h, _ in calls] == [digest, digest]
    assert calls[0][1] != calls[1][1]
    assert crypto.dverify(tag, pub, digest)
    # and the retried tag is batch-compatible like any other
    assert crypto.verify_batch([(tag, pub, digest)]).ok
