"""HCDS crypto primitives: SHA-256 commitment + secp256k1 ECDSA."""

import hashlib

import pytest

from repro.core import crypto


def test_sha256_matches_hashlib():
    assert crypto.sha256_digest(b"ab", b"cd") == hashlib.sha256(b"abcd").digest()


def test_keypair_deterministic_from_seed():
    k1 = crypto.ECDSAKeyPair.generate(b"seed")
    k2 = crypto.ECDSAKeyPair.generate(b"seed")
    assert k1 == k2
    k3 = crypto.ECDSAKeyPair.generate(b"other")
    assert k1.private_key != k3.private_key


def test_sign_verify_roundtrip():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    d = crypto.sha256_digest(b"model bytes")
    tag = crypto.dsign(d, kp.private_key)
    assert crypto.dverify(tag, kp.public_key, d)


def test_verify_rejects_wrong_digest():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    tag = crypto.dsign(crypto.sha256_digest(b"m"), kp.private_key)
    assert not crypto.dverify(tag, kp.public_key, crypto.sha256_digest(b"m2"))


def test_verify_rejects_wrong_key():
    kp0 = crypto.ECDSAKeyPair.generate(b"node-0")
    kp1 = crypto.ECDSAKeyPair.generate(b"node-1")
    d = crypto.sha256_digest(b"m")
    tag = crypto.dsign(d, kp0.private_key)
    assert not crypto.dverify(tag, kp1.public_key, d)


def test_verify_rejects_malformed_signature():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    d = crypto.sha256_digest(b"m")
    assert not crypto.dverify((0, 1), kp.public_key, d)
    assert not crypto.dverify((1, 0), kp.public_key, d)


def test_signature_deterministic_rfc6979():
    kp = crypto.ECDSAKeyPair.generate(b"node-0")
    d = crypto.sha256_digest(b"m")
    assert crypto.dsign(d, kp.private_key) == crypto.dsign(d, kp.private_key)


def test_public_key_on_curve():
    kp = crypto.ECDSAKeyPair.generate(b"x")
    x, y = kp.public_key
    p = crypto._P
    assert (y * y - (x * x * x + 7)) % p == 0
