"""Sharded consortium: committees, checkpoint certificates, cross-shard sync.

Four layers of coverage for the PR-10 refactor:

* committee primitives — partition/quorum/id-mapping math, per-committee
  RNG substreams (pinned literals), per-committee signing keys;
* checkpoint-bearing ledgers — a quorum certificate is the block's proof,
  checked through the existing ``retally`` seam: sub-quorum/tampered
  certificates are rejected by ``append`` *and* ``sync_from``, equal-height
  forks resolve deterministically in either order, a rejoining node
  catches up through a checkpoint boundary, and the WAL refuses a
  conflicting countersignature for the same epoch;
* substream isolation — resizing committee 1 leaves committee 0's network
  event stream byte-identical (the satellite-2 determinism pin);
* end-to-end — a mini consortium through ``api.run_bhfl(committees=K)``,
  the committees=1 equivalence pin against the pre-shard path, and the
  registered consortium scenarios (slow) including the N=256 acceptance
  run.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro import api, obs
from repro.blockchain.block import GENESIS_HASH, block_hash
from repro.blockchain.ledger import InvalidBlock, Ledger
from repro.core.committee import (CheckpointStatement, Committee,
                                  checkpoint_block, checkpoint_statement_of,
                                  committee_keypair, committee_seed,
                                  make_checkpoint_validator, make_committees,
                                  sign_checkpoint,
                                  verify_checkpoint_certificate)
from repro.core.recovery import NodeWAL, WALConflict
from repro.sim import Scenario

DIGEST_A = "ab" * 32
DIGEST_B = "cd" * 32


# ---------------------------------------------------------------------------
# Committee primitives
# ---------------------------------------------------------------------------

def test_make_committees_balanced_contiguous():
    coms = make_committees(10, 3)
    assert [c.members for c in coms] == [(0, 1, 2, 3), (4, 5, 6), (7, 8, 9)]
    assert [c.committee_id for c in coms] == [0, 1, 2]
    assert [c.quorum for c in coms] == [3, 2, 2]   # ⌈2m/3⌉ over members


def test_make_committees_explicit_sizes_and_errors():
    coms = make_committees(10, 0, sizes=(2, 5, 3))
    assert [c.size for c in coms] == [2, 5, 3]
    assert coms[1].members == (2, 3, 4, 5, 6)
    with pytest.raises(ValueError):
        make_committees(10, 0, sizes=(2, 5))       # sums to 7, not 10
    with pytest.raises(ValueError):
        make_committees(4, 5)                      # more committees than nodes
    with pytest.raises(ValueError):
        make_committees(0, 1)


def test_committee_id_mapping_round_trips():
    com = make_committees(12, 3)[1]                # members (4, 5, 6, 7)
    for local, gid in enumerate(com.members):
        assert com.global_id(local) == gid
        assert com.local_index(gid) == local
        assert gid in com
    assert 0 not in com
    with pytest.raises(KeyError):
        com.local_index(0)


def test_committee_seed_substreams_are_pinned_and_distinct():
    # pinned literals: these feed SimNetwork seeding, so a silent change
    # here would reshuffle every consortium scenario's traffic
    assert committee_seed(7, -1) == 7510914623393002459   # the cross bus
    assert committee_seed(7, 0) == 5227612850216004114
    assert committee_seed(7, 1) == 5223760991133964594
    assert committee_seed(7, 2) == 3697508751124339522
    seeds = [committee_seed(0, c) for c in range(-1, 8)]
    assert len(set(seeds)) == len(seeds)
    assert all(0 <= s < 2 ** 63 for s in seeds)
    # distinct scenario seeds give distinct substreams for the same cid
    assert committee_seed(0, 0) != committee_seed(1, 0)


def test_committee_keypairs_unique_across_committees():
    # same global node id, different committee tag -> different identity;
    # the consortium key directory can never alias across shards
    assert (committee_keypair(0, 5).public_key
            != committee_keypair(1, 5).public_key)
    assert (committee_keypair(0, 5).public_key
            != committee_keypair(0, 6).public_key)
    # and derivation is deterministic
    assert (committee_keypair(2, 3).public_key
            == committee_keypair(2, 3).public_key)


# ---------------------------------------------------------------------------
# Checkpoint certificates on the top-chain ledger (satellite 3)
# ---------------------------------------------------------------------------

def _consortium_keys(n=8, k=2):
    coms = make_committees(n, k)
    kps = {gid: committee_keypair(com.committee_id, gid)
           for com in coms for gid in com.members}
    pks = {gid: kp.public_key for gid, kp in kps.items()}
    validator = make_checkpoint_validator(
        {c.committee_id: c for c in coms}, pks)
    return coms, kps, pks, validator


def _cp_block(com, kps, top, epoch, digest=DIGEST_A, signers=None):
    stmt = CheckpointStatement(com.committee_id, epoch, 1, GENESIS_HASH,
                               digest)
    signers = com.members if signers is None else signers
    cert = {gid: sign_checkpoint(stmt, gid, kps[gid]).signature
            for gid in signers}
    leader = com.members[0]
    return checkpoint_block(stmt, cert, top, leader, kps[leader])


def test_checkpoint_block_with_full_quorum_appends():
    coms, kps, pks, validator = _consortium_keys()
    top = Ledger(0)
    blk = _cp_block(coms[0], kps, top, epoch=0)
    assert validator(blk) == blk.leader_id
    top.append(blk, leader_pk=pks[blk.leader_id], retally=validator)
    assert top.height == 1 and top.verify_chain(pks)
    stmt = checkpoint_statement_of(top.blocks[0])
    assert stmt is not None and stmt.committee_id == 0 and stmt.epoch == 0


def test_sub_quorum_certificate_rejected_on_append_and_sync():
    coms, kps, pks, validator = _consortium_keys()
    com = coms[0]                                  # 4 members, quorum 3
    blk = _cp_block(com, kps, Ledger(0), epoch=0,
                    signers=com.members[:2])       # 2 < 3
    assert validator(blk) == -1
    with pytest.raises(InvalidBlock):
        Ledger(0).append(blk, leader_pk=pks[blk.leader_id],
                         retally=validator)
    with pytest.raises(InvalidBlock):
        Ledger(1).sync_from([blk], pks, retally=validator)


def test_foreign_committee_signatures_do_not_count():
    coms, kps, pks, validator = _consortium_keys()
    com0, com1 = coms
    stmt = CheckpointStatement(0, 0, 1, GENESIS_HASH, DIGEST_A)
    # one real member + every member of the OTHER committee: still 1 < 3
    cert = {gid: sign_checkpoint(stmt, gid, kps[gid]).signature
            for gid in (com0.members[0],) + com1.members}
    assert not verify_checkpoint_certificate(stmt, cert, com0, pks)
    blk = checkpoint_block(stmt, cert, Ledger(0), com0.members[0],
                           kps[com0.members[0]])
    assert validator(blk) == -1


def test_certificate_does_not_transfer_across_epochs():
    coms, kps, pks, _ = _consortium_keys()
    com = coms[0]
    stmt0 = CheckpointStatement(0, 0, 1, GENESIS_HASH, DIGEST_A)
    cert0 = {gid: sign_checkpoint(stmt0, gid, kps[gid]).signature
             for gid in com.members}
    # the quorum that certified epoch 0 proves nothing about epoch 1
    stmt1 = dataclasses.replace(stmt0, epoch=1)
    assert verify_checkpoint_certificate(stmt0, cert0, com, pks)
    assert not verify_checkpoint_certificate(stmt1, cert0, com, pks)


def test_tampered_model_digest_rejected_even_if_resigned():
    coms, kps, pks, validator = _consortium_keys()
    top = Ledger(0)
    blk = _cp_block(coms[0], kps, top, epoch=0)
    leader = blk.leader_id
    # a leader that re-signs a block whose body digest no longer matches
    # the certified statement has a valid signature but an invalid proof
    forged = dataclasses.replace(blk, global_model_digest=DIGEST_B,
                                 leader_signature=None).signed(kps[leader])
    assert forged.verify_signature(pks[leader])
    assert validator(forged) == -1
    with pytest.raises(InvalidBlock):
        top.append(forged, leader_pk=pks[leader], retally=validator)


def test_equal_height_checkpoint_forks_resolve_deterministically():
    coms, kps, pks, validator = _consortium_keys()
    led_a, led_b = Ledger(0), Ledger(1)
    blk_a = _cp_block(coms[0], kps, led_a, epoch=0, digest=DIGEST_A)
    blk_b = _cp_block(coms[1], kps, led_b, epoch=0, digest=DIGEST_B)
    led_a.append(blk_a, leader_pk=pks[blk_a.leader_id], retally=validator)
    led_b.append(blk_b, leader_pk=pks[blk_b.leader_id], retally=validator)
    assert led_a.head_hash != led_b.head_hash
    # the production merge path pre-validates every candidate certificate
    # before fork choice — mirror it here
    for blocks in (led_a.blocks, led_b.blocks):
        assert all(validator(b) == b.leader_id for b in blocks)
    winner = min(led_a.head_hash, led_b.head_hash)
    swapped_a = led_a.fork_choice(list(led_b.blocks), pks)
    swapped_b = led_b.fork_choice(list(led_a.blocks), pks)
    # exactly one side switches (the one holding the larger head hash),
    # and both converge on the lexicographically smaller head
    assert swapped_a != swapped_b
    assert led_a.head_hash == led_b.head_hash == winner


def test_rejoining_ledger_catches_up_through_checkpoint_boundary():
    coms, kps, pks, validator = _consortium_keys()
    full = Ledger(0)
    for epoch, com in enumerate((coms[0], coms[1], coms[0])):
        # sub_head describes the *subchain*; the top-chain linkage is the
        # block's own prev_hash, supplied by checkpoint_block
        stmt = CheckpointStatement(com.committee_id, epoch, epoch + 1,
                                   GENESIS_HASH, DIGEST_A)
        cert = {gid: sign_checkpoint(stmt, gid, kps[gid]).signature
                for gid in com.members}
        blk = checkpoint_block(stmt, cert, full, com.members[0],
                               kps[com.members[0]])
        full.append(blk, leader_pk=pks[blk.leader_id], retally=validator)
    assert full.height == 3

    # a node that crashed after the first checkpoint resyncs the suffix,
    # re-validating every certificate through the retally seam
    stale = Ledger(1)
    stale.append(full.blocks[0], leader_pk=pks[full.blocks[0].leader_id],
                 retally=validator)
    assert stale.sync_from(full.blocks, pks, retally=validator) == 2
    assert stale.head_hash == full.head_hash

    # a brand-new member syncs the whole chain
    fresh = Ledger(2)
    assert fresh.sync_from(full.blocks, pks, retally=validator) == 3
    assert fresh.head_hash == full.head_hash

    # a diverged history is refused by catch-up sync and must go through
    # fork choice (after certificate pre-validation) instead
    diverged = Ledger(3)
    other = _cp_block(coms[1], kps, diverged, epoch=0, digest=DIGEST_B)
    diverged.append(other, leader_pk=pks[other.leader_id],
                    retally=validator)
    with pytest.raises(InvalidBlock):
        diverged.sync_from(full.blocks, pks, retally=validator)
    assert all(validator(b) == b.leader_id for b in full.blocks)
    assert diverged.fork_choice(list(full.blocks), pks)
    assert diverged.head_hash == full.head_hash


def test_wal_refuses_conflicting_checkpoint_countersignature():
    coms, kps, _, _ = _consortium_keys()
    com = coms[0]
    gid = com.members[0]
    wal = NodeWAL(gid)
    stmt = CheckpointStatement(0, 0, 1, GENESIS_HASH, DIGEST_A)
    sign_checkpoint(stmt, gid, kps[gid], wal=wal)
    # re-signing the SAME statement is idempotent (a rebroadcast)...
    sign_checkpoint(stmt, gid, kps[gid], wal=wal)
    # ...but a conflicting statement for the same epoch is equivocation
    conflicting = dataclasses.replace(stmt, global_model_digest=DIGEST_B)
    with pytest.raises(WALConflict):
        sign_checkpoint(conflicting, gid, kps[gid], wal=wal)
    # a later epoch is a fresh slot
    sign_checkpoint(dataclasses.replace(conflicting, epoch=1), gid,
                    kps[gid], wal=wal)


# ---------------------------------------------------------------------------
# Satellite 2: per-committee RNG substream isolation
# ---------------------------------------------------------------------------

def _committee0_net_trace(n_nodes, sizes):
    """Committee 0's commit/reveal network event stream (seq and wall
    clock dropped — those are recorder bookkeeping, not protocol)."""
    sc = Scenario(
        name="substream_probe",
        description="substream isolation probe (test-only)",
        rounds=2, n_nodes=n_nodes, clients_per_node=1,
        committees=2, committee_sizes=sizes, checkpoint_interval=2,
        n_train=80, n_test=16)
    rec = obs.TraceRecorder("substream_probe")
    with obs.use_recorder(rec):
        api.run_bhfl(scenario=sc, seed=3)
    keep = ("net_delivery", "net_exchange", "net_retransmit", "net_timeout")
    trace = []
    for e in rec.events:
        attrs = dict(e.attrs)
        if e.name not in keep or attrs.get("committee") != 0:
            continue
        if attrs.get("kind") not in ("commit", "reveal"):
            continue
        trace.append((e.name, e.round, e.node, e.sim_ms,
                      tuple(sorted(attrs.items()))))
    return trace


def test_resizing_committee_1_leaves_committee_0_traffic_identical():
    # committee 0 keeps members 0..7 and its committee_seed substream in
    # both runs; committee 1 grows 8 -> 12. Every committee-0 bus draw
    # (jitter per message, drops) must replay identically — one shared
    # stream across committees would shift here as an event diff.
    small = _committee0_net_trace(16, (8, 8))
    grown = _committee0_net_trace(20, (8, 12))
    assert len(small) >= 200          # 2 rounds x 2 kinds x 8x7 deliveries
    assert small == grown


# ---------------------------------------------------------------------------
# End-to-end: mini consortium through the api facade
# ---------------------------------------------------------------------------

MINI = Scenario(
    name="consortium_mini",
    description="3 committees of 4 on a clean bus (test-only)",
    rounds=2, n_nodes=12, clients_per_node=1,
    committees=3, checkpoint_interval=1,
    n_train=96, n_test=32)


def test_mini_consortium_end_to_end():
    run = api.run_bhfl(scenario=MINI, seed=0)
    rep = run.scenario_report
    assert rep is not None and rep.committees == 3
    assert rep.n_nodes == 12 and rep.quorum == 3       # ⌈2·4/3⌉ per shard

    # per-committee rollup: every shard lived through both rounds and
    # emitted one certified checkpoint per epoch
    assert [c.committee_id for c in rep.committee_reports] == [0, 1, 2]
    assert rep.committee_reports[0].members == [0, 1, 2, 3]
    assert rep.committee_reports[2].members == [8, 9, 10, 11]
    for c in rep.committee_reports:
        assert c.liveness and c.completed_rounds == 2
        assert c.checkpoints_emitted == 2              # interval=1, 2 rounds
        assert c.checkpoints_merged == 4               # 2 peers x 2 epochs
        assert c.converged and c.safety_violations == 0

    # global verdict: liveness everywhere, zero safety violations, and the
    # top-chain serialized K checkpoints per epoch on every committee
    assert rep.liveness and rep.completed_rounds == 2
    assert rep.safety_violations == 0 and rep.converged
    assert rep.top_chain_height == 6                   # 2 epochs x 3 shards
    assert rep.top_chain_converged
    assert rep.cross_shard_checkpoints == 12

    # merged rounds carry committee identity with node ids globalized
    assert {r.committee for r in rep.rounds} == {0, 1, 2}
    c2_rounds = [r for r in rep.rounds if r.committee == 2]
    assert c2_rounds and all(set(r.heads) <= {8, 9, 10, 11}
                             for r in c2_rounds)
    assert set(rep.final_heights) == set(range(12))

    # traffic is accounted per committee plus the cross-shard bus
    assert "c0:commit" in rep.net_stats
    assert "xshard:checkpoint" in rep.net_stats
    assert rep.net_stats["xshard:checkpoint"]["delivered"] > 0

    # the facade stays coherent: runtime facade, rewards, summary rollup
    assert run.runtime.verify_chains()
    assert run.chain_height == 2                       # shard-0 subchain
    assert len(run.history) == 6                       # K x rounds
    counts = run.leader_counts
    assert set(counts) == set(range(12)) and sum(counts.values()) == 6
    text = rep.summary()
    assert "committee 0" in text and "top-chain:" in text
    d = rep.to_dict()
    assert d["committees"] == 3 and len(d["committee_reports"]) == 3


def test_explicit_committees_1_matches_pre_shard_path():
    # the committees=1 equivalence pin: an explicit K=1 must run the
    # single-committee path byte-for-byte (it is the K=1 bench baseline)
    base = api.run_bhfl(scenario="byzantine_third", seed=0)
    explicit = api.run_bhfl(scenario="byzantine_third", seed=0,
                            committees=1)
    assert base.scenario_report.to_dict() == \
        explicit.scenario_report.to_dict()
    assert [(m.round, m.leader_id, float(m.test_loss)) for m in base.history] \
        == [(m.round, m.leader_id, float(m.test_loss))
            for m in explicit.history]
    # and a K=1 report keeps the pre-shard shape: no committee section
    assert base.scenario_report.committees == 1
    assert base.scenario_report.committee_reports == []
    assert "committee 0" not in explicit.scenario_report.summary()


def test_consortium_rejects_intra_committee_partitions():
    from repro.sim.network import NetworkConfig, PartitionSpec
    bad = dataclasses.replace(
        MINI, net=NetworkConfig(partitions=(
            PartitionSpec(groups=((0, 1), (2, 3)), start_round=0,
                          end_round=1),)))
    with pytest.raises(ValueError, match="cross_net"):
        api.run_bhfl(scenario=bad, seed=0)


# ---------------------------------------------------------------------------
# Registered consortium scenarios (slow: full N=64 / N=256 runs)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_consortium_64_scenario():
    rep = api.run_bhfl(scenario="consortium_64", seed=0).scenario_report
    assert rep.committees == 4 and rep.liveness
    assert rep.safety_violations == 0 and rep.converged
    assert all(c.liveness and c.checkpoints_emitted == 2
               for c in rep.committee_reports)
    assert rep.top_chain_height == 8                   # 2 epochs x 4 shards
    assert rep.top_chain_converged
    # the lossy WAN actually exercised the retry layer
    assert rep.retransmits > 0


@pytest.mark.slow
def test_consortium_partitioned_forks_then_reconverges():
    rep = api.run_bhfl(scenario="consortium_partitioned",
                       seed=0).scenario_report
    # each side kept certifying on its own fork during the cut...
    assert all(c.checkpoints_emitted == 4 for c in rep.committee_reports)
    merged = [c.checkpoints_merged for c in rep.committee_reports]
    assert len(set(merged)) > 1       # the two sides saw different traffic
    # ...and the final sync reconverged the top-chains with no safety
    # violations: concurrent checkpoints under a partition are fork-choice
    # fodder, not equivocation
    assert rep.top_chain_converged and rep.safety_violations == 0
    assert 8 <= rep.top_chain_height <= 16
    assert rep.liveness and rep.converged


@pytest.mark.slow
def test_consortium_committee_crash_recovers():
    rep = api.run_bhfl(scenario="consortium_committee_crash",
                       seed=0).scenario_report
    assert rep.recoveries >= 1        # WAL replay + ledger re-sync rejoin
    assert rep.liveness and rep.safety_violations == 0
    assert all(c.liveness for c in rep.committee_reports)
    # the crashed member's committee still certified every epoch (quorum
    # is over members, and 15 of 16 survivors clear ⌈32/3⌉)
    assert all(c.checkpoints_emitted == 2 for c in rep.committee_reports)
    assert rep.top_chain_converged and rep.converged


@pytest.mark.slow
def test_consortium_256_acceptance():
    # the PR acceptance pin: K=8 at N=256 completes with per-committee
    # liveness all-true and zero global safety violations
    run = api.run_bhfl(scenario="consortium_256", seed=0)
    rep = run.scenario_report
    assert rep.committees == 8 and rep.n_nodes == 256
    assert all(c.liveness for c in rep.committee_reports)
    assert rep.liveness
    assert rep.safety_violations == 0
    assert rep.converged and rep.top_chain_converged
    assert rep.cross_shard_checkpoints > 0
    assert run.runtime.verify_chains()
