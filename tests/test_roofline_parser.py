"""HLO collective parser + trip-count scaling + analytic cost model."""

import pytest

from repro.configs import get_config
from repro.configs.shapes import INPUT_SHAPES
from repro.launch.costs import decode_cost, prefill_cost, train_cost
from repro.launch.roofline import (CollectiveStats, parse_collectives,
                                   _multipliers, _split_computations)
from repro.models.model_api import Model

SAMPLE_HLO = """
HloModule jit_step

%body.1 (param: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
  %param = (s32[], f32[4,16]{1,0}) parameter(0)
  %all-gather = f32[4,64]{0,1} all-gather(%copy), channel_id=1, dimensions={1}
  %all-reduce.9 = f32[4,16]{1,0} all-reduce(%dot), channel_id=2
}

%cond.1 (param.1: (s32[], f32[4,16])) -> pred[] {
  %constant.18 = s32[] constant(6)
  ROOT %cmp = pred[] compare(%gte, %constant.18), direction=LT
}

ENTRY %main_spmd (p0: f32[6,32,16], p1: f32[4,64]) -> f32[] {
  %while.8 = (s32[], f32[4,16]{1,0}) while(%tuple.4), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"6"}}
  ROOT %all-reduce.1 = f32[] all-reduce(%wrapped_reduce), channel_id=4
}
"""


def test_split_computations():
    comps = _split_computations(SAMPLE_HLO)
    assert "%body.1" in comps and "%cond.1" in comps
    entries = [c for c, (is_entry, _) in comps.items() if is_entry]
    assert entries == ["%main_spmd"]


def test_trip_count_multipliers():
    comps = _split_computations(SAMPLE_HLO)
    mult = _multipliers(comps)
    assert mult["%main_spmd"] == 1
    assert mult["%body.1"] == 6


def test_collective_bytes_scaled_by_trip_count():
    stats = parse_collectives(SAMPLE_HLO)
    # body: all-gather f32[4,64] = 1024B ×6 ; all-reduce f32[4,16] = 256B ×6
    # entry: all-reduce f32[] = 4B ×1
    assert stats.bytes_by_kind["all-gather"] == 1024 * 6
    assert stats.bytes_by_kind["all-reduce"] == 256 * 6 + 4
    assert stats.count_by_kind["all-gather"] == 6
    assert stats.count_by_kind["all-reduce"] == 7


def test_unscaled_parse():
    stats = parse_collectives(SAMPLE_HLO, scale_by_trip_count=False)
    assert stats.bytes_by_kind["all-gather"] == 1024
    assert stats.count_by_kind["all-reduce"] == 2


# ---------------------------------------------------------------------------
# analytic cost model sanity
# ---------------------------------------------------------------------------

def test_train_flops_exceed_6nd():
    """Train cost ≥ 6·N·D (the matmul floor) for a dense arch."""
    model = Model(get_config("yi-6b"))
    shape = INPUT_SHAPES["train_4k"]
    c = train_cost(model, shape, n_clusters=8)
    floor = 6.0 * model.n_params() * shape.global_batch * shape.seq_len
    assert c.flops > floor
    assert c.flops < 4 * floor      # remat+attention shouldn't 4x it


def test_moe_train_flops_use_active_params():
    dense = Model(get_config("yi-6b"))
    moe = Model(get_config("phi3.5-moe-42b-a6.6b"))
    assert moe.n_params() > 5 * moe.n_active_params() * 0.8
    shape = INPUT_SHAPES["train_4k"]
    # 42B-total MoE trains with ~6.6B active → flops comparable to yi-6b
    c_moe = train_cost(moe, shape, 8)
    c_dense = train_cost(dense, shape, 8)
    assert c_moe.flops < 4 * c_dense.flops


def test_decode_window_caps_attention():
    full = Model(get_config("mistral-nemo-12b"))
    win = Model(get_config("mistral-nemo-12b").with_sliding_window(8192))
    shape = INPUT_SHAPES["long_500k"]
    assert decode_cost(win, shape).flops < decode_cost(full, shape).flops


def test_prefill_scales_with_seq():
    model = Model(get_config("starcoder2-3b"))
    s32 = INPUT_SHAPES["prefill_32k"]
    c = prefill_cost(model, s32)
    assert c.flops > 2.0 * model.n_params() * s32.global_batch * s32.seq_len


def test_ssm_decode_cost_constant_in_seq():
    """rwkv6 decode reads O(1) state — long_500k ≈ decode_32k per token."""
    model = Model(get_config("rwkv6-1.6b"))
    c_long = decode_cost(model, INPUT_SHAPES["long_500k"])
    c_32k = decode_cost(model, INPUT_SHAPES["decode_32k"])
    per_tok_long = c_long.hbm_bytes / 1
    per_tok_32k = c_32k.hbm_bytes / 128
    assert per_tok_long < per_tok_32k * 130     # no 16x seq blowup
