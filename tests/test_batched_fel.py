"""Batched in-graph FEL engine ↔ per-client reference loop parity.

The batched engine (``repro.fl.batched_fel``) must be a pure perf
transformation of the reference loop: same seeds → (all-but-)identical
parameters every round and the identical leader sequence, including
ragged/empty client shards and the plagiarist attack path.
"""

import numpy as np
import pytest

from repro.core.serialization import flatten_pytree
from repro.data.synthetic import make_mnist_like
from repro.fl.client import Client
from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime
from repro.fl.hierarchy import FELCluster, build_hierarchy
from repro.models.mlp import MLPConfig


def _global_flat(rt: BHFLRuntime) -> np.ndarray:
    if rt._global_flat is not None:
        return np.asarray(rt._global_flat)
    return np.asarray(flatten_pytree(rt.global_params))


def _run_both(make_runtime, rounds=3, **kw):
    ref = make_runtime("reference", **kw)
    bat = make_runtime("batched", **kw)
    assert ref.engine == "reference" and bat.engine == "batched"
    out = []
    for _ in range(rounds):
        m_ref = ref.run_round()
        m_bat = bat.run_round()
        out.append((m_ref, m_bat, _global_flat(ref), _global_flat(bat)))
    return out


# ---------------------------------------------------------------------------
# uniform IID shards (the bench configuration, scaled down)
# ---------------------------------------------------------------------------

def test_parity_uniform_iid():
    train, test = make_mnist_like(n_train=720, n_test=60)

    def make(engine):
        cfg = BHFLConfig(n_nodes=3, clients_per_node=2, fel_iterations=2,
                         engine=engine)
        return BHFLRuntime(build_hierarchy(train, 3, 2, "iid"), cfg, test)

    for r, (m_ref, m_bat, g_ref, g_bat) in enumerate(_run_both(make, rounds=3)):
        assert m_ref.leader_id == m_bat.leader_id, f"leader diverged @ round {r}"
        # uniform shards reduce in the identical order → bit-equal params
        np.testing.assert_allclose(g_ref, g_bat, rtol=1e-6, atol=1e-7)
        assert m_ref.test_accuracy == pytest.approx(m_bat.test_accuracy,
                                                    abs=1e-6)
        np.testing.assert_allclose(np.asarray(m_ref.consensus.similarities),
                                   np.asarray(m_bat.consensus.similarities),
                                   rtol=1e-6, atol=1e-7)


def test_parity_multi_epoch_and_multi_batch():
    """Several SGD steps per iteration (epochs × batches) keep the PRNG
    split sequence and lr-decay step counter aligned."""
    train, _ = make_mnist_like(n_train=600, n_test=10)

    def make(engine):
        cfg = BHFLConfig(n_nodes=2, clients_per_node=2, fel_iterations=2,
                         local_epochs=2, batch_size=32, engine=engine)
        return BHFLRuntime(build_hierarchy(train, 2, 2, "iid"), cfg, None)

    for r, (m_ref, m_bat, g_ref, g_bat) in enumerate(_run_both(make, rounds=3)):
        assert m_ref.leader_id == m_bat.leader_id
        np.testing.assert_allclose(g_ref, g_bat, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ragged / empty shards
# ---------------------------------------------------------------------------

def _ragged_clusters(train, sizes):
    clusters, cid, off = [], 0, 0
    for nid, row in enumerate(sizes):
        clients = []
        for s in row:
            idx = np.arange(off, off + s)
            off += s
            clients.append(Client(cid, train.subset(idx)))
            cid += 1
        clusters.append(FELCluster(nid, clients))
    return clusters


def test_parity_ragged_and_empty_shards():
    """Ragged client sizes, an empty client, and a fully dataless cluster:
    the masked batched reduction must agree with the skip-empty reference
    semantics (the dataless cluster keeps the incoming global model)."""
    train, _ = make_mnist_like(n_train=400, n_test=10)
    sizes = [[70, 37, 0], [12, 90, 3], [0, 0, 0]]

    def make(engine):
        cfg = BHFLConfig(n_nodes=3, clients_per_node=3, fel_iterations=2,
                         engine=engine)
        return BHFLRuntime(_ragged_clusters(train, sizes), cfg, None)

    for r, (m_ref, m_bat, g_ref, g_bat) in enumerate(_run_both(make, rounds=3)):
        assert m_ref.leader_id == m_bat.leader_id
        # padded masked reductions reorder a handful of float adds
        np.testing.assert_allclose(g_ref, g_bat, rtol=1e-5, atol=1e-6)


def test_dataless_cluster_keeps_global_model():
    train, _ = make_mnist_like(n_train=200, n_test=10)
    sizes = [[50, 50], [0, 0]]

    def make(engine):
        cfg = BHFLConfig(n_nodes=2, clients_per_node=2, fel_iterations=2,
                         engine=engine)
        return BHFLRuntime(_ragged_clusters(train, sizes), cfg, None)

    bat = make("batched")
    start = np.asarray(bat._global_flat)
    W = bat._engine.run_round(bat._global_flat, round_seed=1)
    np.testing.assert_array_equal(np.asarray(W[1]), start)
    assert not np.array_equal(np.asarray(W[0]), start)


# ---------------------------------------------------------------------------
# plagiarist attack path
# ---------------------------------------------------------------------------

def test_parity_plagiarist_path():
    train, _ = make_mnist_like(n_train=600, n_test=10)

    def make(engine):
        cfg = BHFLConfig(n_nodes=3, clients_per_node=2, fel_iterations=1,
                         engine=engine)
        rt = BHFLRuntime(build_hierarchy(train, 3, 2, "iid"), cfg, None)
        rt.plagiarists = {1}
        return rt

    for r, (m_ref, m_bat, g_ref, g_bat) in enumerate(_run_both(make, rounds=3)):
        assert m_ref.leader_id == m_bat.leader_id
        np.testing.assert_allclose(g_ref, g_bat, rtol=1e-6, atol=1e-7)
        # HCDS flags the byte-identical copy identically on both paths
        assert m_ref.consensus.rejected == m_bat.consensus.rejected
        assert "plagiarized-model" in m_bat.consensus.rejected.values()


# ---------------------------------------------------------------------------
# engine selection / fallback
# ---------------------------------------------------------------------------

class _NoBatchAdapter:
    """Minimal adapter without batched_train_spec (protocol minimum)."""

    name = "no-batch"

    def __init__(self):
        from repro.fl.adapters import MLPAdapter
        self._inner = MLPAdapter(cfg=MLPConfig(hidden=8))

    def init(self, key):
        return self._inner.init(key)

    def local_train(self, params, client, *, seed=0):
        return self._inner.local_train(params, client, seed=seed)

    def evaluate(self, params, dataset):
        return self._inner.evaluate(params, dataset)

    def flatten(self, params):
        return self._inner.flatten(params)

    def unflatten(self, flat, template):
        return self._inner.unflatten(flat, template)


def test_engine_flag_validation_and_fallback():
    train, _ = make_mnist_like(n_train=200, n_test=10)
    clusters = build_hierarchy(train, 2, 2, "iid")
    cfg = BHFLConfig(n_nodes=2, clients_per_node=2, engine="nope")
    with pytest.raises(ValueError, match="unknown engine"):
        BHFLRuntime(clusters, cfg, None)

    cfg = BHFLConfig(n_nodes=2, clients_per_node=2,
                     mlp=MLPConfig(hidden=8), engine="batched")
    with pytest.raises(ValueError, match="batched_train_spec"):
        BHFLRuntime(build_hierarchy(train, 2, 2, "iid"), cfg, None,
                    adapter=_NoBatchAdapter())

    cfg = BHFLConfig(n_nodes=2, clients_per_node=2,
                     mlp=MLPConfig(hidden=8), engine="auto")
    rt = BHFLRuntime(build_hierarchy(train, 2, 2, "iid"), cfg, None,
                     adapter=_NoBatchAdapter())
    assert rt.engine == "reference"
    rt.run_round()     # fallback path still completes a round

    cfg = BHFLConfig(n_nodes=2, clients_per_node=2,
                     mlp=MLPConfig(hidden=8), engine="auto")
    rt = BHFLRuntime(build_hierarchy(train, 2, 2, "iid"), cfg, None)
    assert rt.engine == "batched"


def test_lm_adapter_batched_engine_runs():
    """LM adapters opt in to the batched engine; bf16 params mean the two
    engines only track loosely (the reference loop promotes to f32 after
    step 1, the engine trains in f32 throughout), so this is a smoke +
    shape test, not a strict parity pin."""
    from repro.data.tokens import make_token_dataset
    from repro.fl.adapters import transformer_adapter

    train, test = make_token_dataset(n_seqs=64, seq_len=8, vocab_size=32)
    cfg = BHFLConfig(n_nodes=2, clients_per_node=2, fel_iterations=1,
                     engine="batched")
    ad = transformer_adapter(vocab_size=32, d_model=16, n_layers=1)
    rt = BHFLRuntime(build_hierarchy(train, 2, 2, "iid"), cfg, test,
                     adapter=ad)
    m = rt.run_round()
    assert np.isfinite(m.test_loss)
    assert rt._global_flat.shape[0] == flatten_pytree(rt.global_params).shape[0]


# ---------------------------------------------------------------------------
# shape bucketing / compile-cache reuse
# ---------------------------------------------------------------------------

def test_shape_bucketing_reuses_compiled_round():
    """Runtimes rebuilt at nearby scales (3 vs 4 clients, 96 vs 128 samples
    per shard — same pow2 buckets) must reuse one compiled round program;
    a scale in a different bucket must trace fresh. Bucketed padding is
    masked, so the padded program's output is bit-identical to the exact
    one."""
    from repro.fl import batched_fel
    from repro.fl.adapters import MLPAdapter
    from repro.models.mlp import MLPConfig

    adapter = MLPAdapter(cfg=MLPConfig(hidden=8))

    def runtime(clients, per_client, bucketing=True):
        train, _ = make_mnist_like(n_train=2 * clients * per_client,
                                   n_test=10)
        cfg = BHFLConfig(n_nodes=2, clients_per_node=clients,
                         fel_iterations=1, mlp=MLPConfig(hidden=8),
                         engine="batched", shape_bucketing=bucketing)
        return BHFLRuntime(build_hierarchy(train, 2, clients, "iid"), cfg,
                           None, adapter=adapter)

    rt1 = runtime(3, 96)
    rt1.run_round()
    count = batched_fel.compile_count()
    assert rt1._engine.n_clients_padded == 4
    assert rt1._engine.n_max == 128

    rt2 = runtime(4, 128)               # same buckets: (4 clients, 128, ...)
    rt2.run_round()
    assert batched_fel.compile_count() == count     # cache hit, no re-trace
    assert rt2._engine._round_fn is rt1._engine._round_fn

    rt3 = runtime(5, 96)                # 5 clients -> pad 8: a new bucket
    rt3.run_round()
    assert batched_fel.compile_count() == count + 1

    # bucketed padding is bit-exact against the unbucketed program
    # (same starting global model through both engines)
    exact = runtime(3, 96, bucketing=False)
    start = exact._global_flat
    W_exact = np.asarray(exact._engine.run_round(start, 1))
    W_bucket = np.asarray(rt1._engine.run_round(start, 1))
    np.testing.assert_array_equal(W_exact, W_bucket)


def test_api_engine_kwarg():
    from repro import api
    run = api.run_bhfl(model="mlp", n_nodes=2, clients_per_node=2,
                       fel_iterations=1, rounds=2, engine="batched")
    assert run.runtime.engine == "batched"
    assert run.chain_valid and run.chain_height == 2
