"""BTSV (Eq. 3-10, Alg. 4): scores, weights, tallying, attack resistance."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.btsv import (BTSVConfig, bts_scores, btsv_round, init_history,
                             vote_weights, votes_to_matrix)


def _preds(votes, n, g_max=0.99):
    g_min = (1 - g_max) / (n - 1)
    P = np.full((n, n), g_min, np.float32)
    P[np.arange(n), votes] = g_max
    return jnp.asarray(P)


def test_unanimous_vote_elects_leader():
    n = 10
    votes = jnp.asarray([3] * n)
    res, _ = btsv_round(votes, _preds(np.array([3] * n), n), init_history(n))
    assert int(res.leader) == 3


def test_vote_weight_sigmoid_properties():
    cfg = BTSVConfig()
    # WV(0) ≈ 1 (paper §7.4: ε makes WV=1 at CHS=0)
    w0 = float(vote_weights(jnp.asarray(0.0), cfg))
    assert w0 == pytest.approx(cfg.beta / (1 + np.exp(-cfg.epsilon)), abs=1e-6)
    assert 0.9 < w0 < 1.1
    # monotone increasing, bounded by beta
    chs = jnp.linspace(-20, 20, 41)
    w = np.asarray(vote_weights(chs, cfg))
    assert np.all(np.diff(w) > 0)
    assert np.all(w <= cfg.beta) and np.all(w >= 0)


def test_zero_sum_scores_when_alpha_one():
    """With α=1 and everyone using the same G_max/G_min prediction scheme,
    Σ_i score_i ≈ 0 only in the symmetric case; we verify the documented
    zero-sum property for unanimous honest voting."""
    n = 8
    votes = np.array([2] * n)
    A = votes_to_matrix(jnp.asarray(votes), n)
    scores = bts_scores(A, _preds(votes, n))
    # unanimous: x̄ = one-hot, predictions G_max on the same index —
    # info = log(1/0.99) > 0, prediction penalizes log(0.99/1) — net ≈ 0
    assert abs(float(jnp.sum(scores))) < 0.2


def test_minority_dishonest_voters_score_lower():
    n = 10
    votes = np.array([4] * 8 + [7, 7])       # two bribed nodes vote 7
    A = votes_to_matrix(jnp.asarray(votes), n)
    scores = np.asarray(bts_scores(A, _preds(votes, n)))
    assert scores[8] < scores[:8].min()
    assert scores[9] < scores[:8].min()


def test_bribery_does_not_flip_leader_with_history():
    """Targeted attack (paper §7.4 TA): 40% colluders voting node 0 every
    round get down-weighted, so the honest majority's choice wins."""
    n = 10
    n_mal = 4
    cfg = BTSVConfig()
    hist = init_history(n, cfg)
    honest_choice = 5
    votes = np.array([honest_choice] * (n - n_mal) + [0] * n_mal)
    leaders = []
    for k in range(25):
        res, hist = btsv_round(jnp.asarray(votes), _preds(votes, n), hist, cfg)
        leaders.append(int(res.leader))
    assert leaders[-1] == honest_choice
    # malicious weights collapse below honest weights
    res, _ = btsv_round(jnp.asarray(votes), _preds(votes, n), hist, cfg)
    w = np.asarray(res.weights)
    assert w[-n_mal:].max() < w[:n - n_mal].min()


def test_history_window_rolls():
    n = 4
    cfg = BTSVConfig(history=3)
    hist = init_history(n, cfg)
    votes = jnp.asarray([1, 1, 1, 1])
    for _ in range(5):
        res, hist = btsv_round(votes, _preds(np.array([1] * n), n), hist, cfg)
    assert hist.shape == (3, n)
