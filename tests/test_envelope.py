"""Signed-envelope message layer + batch crypto backends.

Property pins (via the optional-hypothesis shim):

* ``verify_batch`` accepts iff every signature individually verifies —
  the batch RLC equation and the per-message dverify loop agree on every
  forged-subset pattern;
* bisection returns exactly the forged indices (attribution);
* ``Signature.to_bytes``/``from_bytes`` round-trips canonically through
  envelope, block, and ledger dict I/O.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.blockchain.block import GENESIS_HASH, Block, block_hash
from repro.blockchain.ledger import Ledger
from repro.blockchain.smart_contract import (VoteSubmission,
                                             VoteTallyContract,
                                             vote_payload_digest)
from repro.core import crypto
from repro.core.envelope import (SignedEnvelope, commit_signing_digest,
                                 verify_envelopes)

_KPS = [crypto.ECDSAKeyPair.generate(bytes([i, 0xEE])) for i in range(8)]


def _batch(n):
    """n distinct (tag, pk, digest) items signed by distinct keys."""
    items = []
    for i in range(n):
        d = crypto.sha256_digest(b"payload", bytes([i]))
        items.append((crypto.dsign(d, _KPS[i].private_key),
                      _KPS[i].public_key, d))
    return items


def _forge(item, mode):
    """One forged variant of a valid item; must fail individual dverify."""
    tag, pk, d = item
    if mode == 0:       # tampered s
        return (crypto.Signature(tag.r, tag.s ^ 0x2, tag.v), pk, d)
    if mode == 1:       # signature transplanted onto a different digest
        return (tag, pk, crypto.sha256_digest(d))
    return (tag, _KPS[7].public_key, d)   # wrong public key


# ---------------------------------------------------------------------------
# verify_batch: accept-iff-all-individually-valid + exact attribution
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 6), mask=st.integers(0, 63),
       mode=st.integers(0, 2))
def test_verify_batch_accepts_iff_each_verifies(n, mask, mode):
    items = _batch(n)
    forged = [i for i in range(n) if (mask >> i) & 1]
    for i in forged:
        items[i] = _forge(items[i], mode)
    individually = [crypto.dverify(t, pk, d) for t, pk, d in items]
    res = crypto.verify_batch(items, backend="batch")
    assert res.ok == all(individually)
    assert list(res.bad) == [i for i, ok in enumerate(individually) if not ok]
    assert list(res.bad) == forged          # bisection attribution is exact
    # and identical under the per-message backends
    for be in ("windowed", "naive"):
        assert crypto.verify_batch(items, backend=be) == res


def test_batch_dedups_receiver_copies():
    """A round's N×(N−1) receiver copies of N distinct tags verify as one
    deduplicated batch, with per-copy attribution preserved."""
    items = _batch(4)
    items[2] = _forge(items[2], 0)
    copies = [it for it in items for _ in range(3)]
    res = crypto.verify_batch(copies, backend="batch")
    assert not res.ok
    assert list(res.bad) == [6, 7, 8]       # all three copies of item 2


def test_tampered_recovery_bit_still_accepts():
    """The recovery bit is a batching hint, not part of the signed
    statement: flipping it defeats the fast batch equation but bisection
    must still accept the (individually valid) signature."""
    (tag, pk, d), = _batch(1)
    flipped = crypto.Signature(tag.r, tag.s, tag.v ^ 1)
    assert crypto.dverify(flipped, pk, d)
    assert crypto.verify_batch([(flipped, pk, d)], backend="batch").ok


def test_legacy_two_tuple_signatures_batch_verify():
    """Pre-envelope (r, s) pairs lack a recovery bit; verify_batch routes
    them through individual verification without rejecting them."""
    items = [(tuple(t)[:2], pk, d) for t, pk, d in _batch(3)]
    assert crypto.verify_batch(items, backend="batch").ok


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown crypto backend"):
        crypto.verify_batch([], backend="quantum")
    with pytest.raises(ValueError, match="unknown crypto backend"):
        crypto.set_backend("quantum")


# ---------------------------------------------------------------------------
# envelopes
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["commit", "reveal", "vote", "block"]),
       round=st.integers(0, 1000), sender=st.integers(0, 7))
def test_envelope_seal_verify_and_dict_roundtrip(kind, round, sender):
    payload = crypto.sha256_digest(b"payload", kind.encode())
    # (kind is drawn from the registry by the strategy above)
    env = SignedEnvelope.seal(kind, round, sender, payload,  # noqa: RA402
                              _KPS[sender].private_key)
    assert env.verify(_KPS[sender].public_key)
    assert not env.verify(_KPS[(sender + 1) % 8].public_key)
    again = SignedEnvelope.from_dict(env.to_dict())
    assert again == env and again.verify(_KPS[sender].public_key)


def test_envelope_domain_separation():
    """The same payload digest signed as a commit must not verify as a
    vote/block envelope — the kind is bound into the signing digest."""
    payload = crypto.sha256_digest(b"w")
    commit = SignedEnvelope.seal("commit", 3, 1, payload,
                                 _KPS[1].private_key)
    for other in ("reveal", "vote", "block"):
        # deliberately replays the tag under a different (registered) kind
        replayed = SignedEnvelope(other, 3, 1, payload, commit.signature)  # noqa: RA402
        assert not replayed.verify(_KPS[1].public_key)
    assert commit_signing_digest(3, 1, payload) == commit.signing_digest()


def test_verify_envelopes_attributes_forged_senders():
    envs = []
    for i in range(5):
        payload = crypto.sha256_digest(bytes([i]))
        key = _KPS[7] if i in (1, 4) else _KPS[i]     # 1 and 4 forge
        envs.append(SignedEnvelope.seal("commit", 0, i, payload,
                                        key.private_key))
    pks = {i: _KPS[i].public_key for i in range(5)}
    res = verify_envelopes(envs, pks)
    assert not res.ok
    assert list(res.bad) == [1, 4]
    assert res.bad_senders(envs) == [1, 4]


def test_verify_envelopes_flags_unknown_sender():
    env = SignedEnvelope.seal("vote", 0, 9, crypto.sha256_digest(b"v"),
                              _KPS[0].private_key)
    res = verify_envelopes([env], {0: _KPS[0].public_key})
    assert not res.ok and res.bad == (0,)


# ---------------------------------------------------------------------------
# Signature canonical serialization (block + ledger I/O)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_signature_bytes_roundtrip(seed):
    kp = crypto.ECDSAKeyPair.generate(seed.to_bytes(4, "big"))
    sig = crypto.dsign(crypto.sha256_digest(seed.to_bytes(4, "big")),
                       kp.private_key)
    assert crypto.Signature.from_bytes(sig.to_bytes()) == sig
    assert crypto.Signature.coerce(sig.to_bytes().hex()) == sig
    assert crypto.Signature.coerce(list(sig)) == sig
    assert crypto.Signature.coerce((sig.r, sig.s)) == (sig.r, sig.s, 0)


def test_block_signature_canonical_in_ledger_io(tmp_path):
    kp = crypto.ECDSAKeyPair.generate(b"leader")
    block = Block(index=0, round=0, leader_id=0, prev_hash=GENESIS_HASH,
                  model_digests={0: "aa"}, global_model_digest="cc",
                  votes={0: 0}, vote_weights={0: 1.0},
                  advotes={0: 1.0}).signed(kp)
    assert isinstance(block.leader_signature, crypto.Signature)
    assert block.verify_signature(kp.public_key)
    assert block.envelope().verify(kp.public_key)
    led = Ledger(0)
    led.append(block, leader_pk=kp.public_key)
    led.save(tmp_path / "chain.json")
    led2 = Ledger.load(tmp_path / "chain.json")
    assert led2.blocks[0].leader_signature == block.leader_signature
    assert isinstance(led2.blocks[0].leader_signature, crypto.Signature)
    assert block_hash(led2.blocks[0]) == block_hash(block)
    assert led2.verify_chain(public_keys={0: kp.public_key})


# ---------------------------------------------------------------------------
# signed vote envelopes in the tally contract
# ---------------------------------------------------------------------------

def _preds(n, vote, g=0.99):
    p = np.full(n, (1 - g) / (n - 1), np.float32)
    p[vote] = g
    return p


def test_contract_drops_forged_vote_with_attribution():
    n = 4
    pks = {i: _KPS[i].public_key for i in range(n)}
    c = VoteTallyContract(n, public_keys=pks)
    for i in range(n):
        sub = VoteSubmission.signed(i, 0, 2, _preds(n, 2),
                                    _KPS[i].private_key)
        if i == 3:      # node 3 signs with a key it does not own
            forged = SignedEnvelope.seal(
                "vote", 0, 3, sub.envelope.payload_digest,
                _KPS[7].private_key)
            sub = VoteSubmission(3, 0, 2, _preds(n, 2), forged)
        c.submit(sub)
    res = c.tally(0, min_submissions=3)
    assert int(res.leader) == 2
    assert c.rejected_votes[0] == {3: "forged-envelope"}


def test_contract_rejects_unbound_envelope_at_submit():
    n = 3
    c = VoteTallyContract(n, public_keys={i: _KPS[i].public_key
                                          for i in range(n)})
    env = SignedEnvelope.seal("vote", 0, 0,
                              vote_payload_digest(0, 0, 2, _preds(n, 2)),
                              _KPS[0].private_key)
    with pytest.raises(Exception, match="does not bind"):
        c.submit(VoteSubmission(0, 0, 1, _preds(n, 1), env))


def test_contract_forged_vote_cannot_prop_up_quorum():
    n = 3
    pks = {i: _KPS[i].public_key for i in range(n)}
    c = VoteTallyContract(n, public_keys=pks)
    c.submit(VoteSubmission.signed(0, 0, 1, _preds(n, 1),
                                   _KPS[0].private_key))
    forged = SignedEnvelope.seal(
        "vote", 0, 1, vote_payload_digest(1, 0, 1, _preds(n, 1)),
        _KPS[7].private_key)
    c.submit(VoteSubmission(1, 0, 1, _preds(n, 1), forged))
    with pytest.raises(Exception, match="1/2 submissions"):
        c.tally(0, min_submissions=2)
