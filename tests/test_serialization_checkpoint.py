"""Deterministic pytree serialization + checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core.serialization import deserialize_pytree_flat, serialize_pytree


def _tree(rng):
    return {"a": rng.normal(size=(3, 4)).astype(np.float32),
            "nested": {"b": rng.integers(0, 9, size=(5,)).astype(np.int32),
                       "c": rng.normal(size=()).astype(np.float64)}}


def test_roundtrip(rng):
    t = _tree(rng)
    flat = deserialize_pytree_flat(serialize_pytree(t))
    assert len(flat) == 3
    by_suffix = {k.split("'")[-2]: v for k, v in flat.items()}
    np.testing.assert_array_equal(by_suffix["a"], t["a"])
    np.testing.assert_array_equal(by_suffix["b"], t["nested"]["b"])
    np.testing.assert_array_equal(by_suffix["c"], t["nested"]["c"])


def test_serialization_key_order_invariant(rng):
    a = rng.normal(size=(2, 2)).astype(np.float32)
    b = rng.normal(size=(3,)).astype(np.float32)
    t1 = {"x": a, "y": b}
    t2 = dict([("y", b), ("x", a)])      # different insertion order
    assert serialize_pytree(t1) == serialize_pytree(t2)


def test_serialization_sensitive_to_values(rng):
    t = _tree(rng)
    s1 = serialize_pytree(t)
    t["a"][0, 0] += 1e-3
    assert serialize_pytree(t) != s1


@settings(deadline=None, max_examples=15)
@given(n=st.integers(1, 30), m=st.integers(1, 7))
def test_serialization_roundtrip_property(n, m):
    rng = np.random.default_rng(n * 100 + m)
    t = {"w": rng.normal(size=(n, m)).astype(np.float32)}
    flat = deserialize_pytree_flat(serialize_pytree(t))
    (arr,) = flat.values()
    np.testing.assert_array_equal(arr, t["w"])


def test_checkpoint_roundtrip(tmp_path, rng):
    t = _tree(rng)
    save_checkpoint(tmp_path, 7, t, metadata={"loss": 1.5})
    assert latest_step(tmp_path) == 7
    loaded = load_checkpoint(tmp_path, 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_integrity_check(tmp_path, rng):
    t = _tree(rng)
    save_checkpoint(tmp_path, 1, t)
    # corrupt the payload
    import numpy as _np
    path = tmp_path / "step_1.npz"
    data = dict(_np.load(path))
    data["leaf_0"] = data["leaf_0"] + 1.0
    _np.savez(path, **data)
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, 1, t)


def test_checkpoint_multiple_steps(tmp_path, rng):
    t = _tree(rng)
    for s in (1, 5, 3):
        save_checkpoint(tmp_path, s, t)
    assert latest_step(tmp_path) == 5
