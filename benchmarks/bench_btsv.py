"""Paper Fig. 7: BTSV vote-weight separation of honest (HN) vs malicious
(MN) nodes under Targeted Attack (TA) and Random Attack (RA), sweeping the
proportion of malicious nodes (20% / 40%) and the chance of behaving
maliciously CBM (0.5 / 0.9). Settings match §7.4: N=50, G_max=0.99,
c=20, β=1.3, θ=0.4, ε=1.2.

derived = mean WV of HNs minus mean WV of MNs after `rounds` rounds
(positive and growing ⇒ the attack is being suppressed).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.btsv import BTSVConfig, btsv_round, init_history

N = 50
ROUNDS = 30
CFG = BTSVConfig(beta=1.3, theta=0.4, epsilon=1.2, history=20)
G_MAX = 0.99


def _preds(votes: np.ndarray) -> jnp.ndarray:
    g_min = (1 - G_MAX) / (N - 1)
    P = np.full((N, N), g_min, np.float32)
    P[np.arange(N), votes] = G_MAX
    return jnp.asarray(P)


def run_attack(attack: str, frac_mal: float, cbm: float, rounds: int = ROUNDS,
               seed: int = 0) -> tuple[float, float]:
    """Returns (us_per_round, WV gap HN−MN at the final round)."""
    rng = np.random.default_rng(seed)
    n_mal = int(N * frac_mal)
    mal = np.arange(N - n_mal, N)
    hist = init_history(N, CFG)
    honest_choice = 7           # the similarity argmax this round
    target = 3                  # TA collusion target
    t0 = time.perf_counter()
    weights = None
    for k in range(rounds):
        votes = np.full(N, honest_choice, np.int64)
        for m in mal:
            if rng.random() < cbm:
                votes[m] = target if attack == "TA" else rng.integers(0, N)
        res, hist = btsv_round(jnp.asarray(votes), _preds(votes), hist, CFG)
        weights = np.asarray(res.weights)
    us = (time.perf_counter() - t0) * 1e6 / rounds
    gap = float(weights[:N - n_mal].mean() - weights[mal].mean())
    return us, gap


def main() -> None:
    for attack in ("TA", "RA"):
        for frac in (0.2, 0.4):
            for cbm in (0.5, 0.9):
                us, gap = run_attack(attack, frac, cbm)
                emit(f"btsv/{attack}/mal{int(frac*100)}/cbm{cbm}", us,
                     f"wv_gap={gap:.4f}")


if __name__ == "__main__":
    main()
