"""Roofline rows from the dry-run sweep (reads dryrun_results.json written
by ``python -m repro.launch.dryrun --all --both-meshes --json ...``).

Emitted as ``name,us_per_call,derived`` where us_per_call is the dominant
roofline term (the step's lower-bound time on the target hardware) and
derived carries the three terms + bottleneck.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("DRYRUN_JSON",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "dryrun_results.json"))


def main() -> None:
    if not os.path.exists(RESULTS):
        print(f"roofline,SKIPPED,no {RESULTS} — run repro.launch.dryrun first")
        return
    rows = json.loads(open(RESULTS).read())
    seen = set()
    for r in rows:
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             bound * 1e6,
             f"dom={r['dominant']};compute={r['compute_s']*1e3:.2f}ms;"
             f"memory={r['memory_s']*1e3:.2f}ms;"
             f"collective={r['collective_s']*1e3:.2f}ms;"
             f"useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    main()
