"""Paper Fig. 8: Stackelberg utilities.

8(a) U_tp vs F (δ=5000)      — inverse relationship
8(b) U_i vs δ (f_i=40)       — linear relationship
8(c) U_tp vs δ (F=1000)      — concave with optimum δ* = F φ/λ
8(d) U_i vs f_i (δ=5000)     — concave with interior optimum

Settings per §7.5: B=500, φ=5, λ=1, μ_i=5, Σf_{−i}=1000, γ_i=0.01.
derived reports the curve values / located optimum.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.incentive import (NodeParams, PublisherParams, best_response,
                                  node_utility, optimal_delta,
                                  publisher_utility, stackelberg_equilibrium)

P = PublisherParams(B=500.0, lam=1.0, phi=5.0)
GAMMA, MU, F_REST = 0.01, 5.0, 1000.0


def fig8a() -> None:
    deltas = jnp.asarray(5000.0)
    for F in (500.0, 1000.0, 2000.0):
        u = float(publisher_utility(deltas, jnp.asarray(F), P))
        emit(f"incentive/utp_vs_F/F{int(F)}", 0.0, f"U_tp={u:.1f}")


def fig8b() -> None:
    f_i = jnp.asarray(40.0)
    for d in (1000.0, 3000.0, 5000.0):
        u = float(node_utility(f_i, jnp.asarray(F_REST), jnp.asarray(d),
                               jnp.asarray(GAMMA), jnp.asarray(MU)))
        emit(f"incentive/ui_vs_delta/d{int(d)}", 0.0, f"U_i={u:.2f}")


def fig8c() -> None:
    F = jnp.asarray(1000.0)
    d_star = float(optimal_delta(F, P))
    u_star = float(publisher_utility(jnp.asarray(d_star), F, P))
    emit("incentive/utp_optimum", 0.0, f"delta*={d_star:.0f};U_tp={u_star:.1f}")
    for d in (0.5 * d_star, 1.5 * d_star):
        u = float(publisher_utility(jnp.asarray(d), F, P))
        assert u < u_star
        emit(f"incentive/utp_vs_delta/d{int(d)}", 0.0, f"U_tp={u:.1f}")


def fig8d() -> None:
    def solve():
        return float(best_response(jnp.asarray(F_REST), jnp.asarray(5000.0),
                                   jnp.asarray(GAMMA), jnp.asarray(MU)))

    us = time_call(solve, repeats=3)
    f_star = solve()
    u_star = float(node_utility(jnp.asarray(f_star), jnp.asarray(F_REST),
                                jnp.asarray(5000.0), jnp.asarray(GAMMA),
                                jnp.asarray(MU)))
    emit("incentive/ui_optimum", us, f"f*={f_star:.1f};U_i={u_star:.2f}")


def bench_full_equilibrium() -> None:
    nodes = NodeParams(jnp.full((50,), GAMMA), jnp.full((50,), MU))

    def solve():
        import jax
        jax.block_until_ready(stackelberg_equilibrium(nodes))

    us = time_call(solve, repeats=3)
    sol = stackelberg_equilibrium(nodes)
    emit("incentive/equilibrium_N50", us,
         f"delta*={float(sol.delta_star):.0f};F*={float(sol.F_star):.0f}")


def main() -> None:
    fig8a()
    fig8b()
    fig8c()
    fig8d()
    bench_full_equilibrium()


if __name__ == "__main__":
    main()
