"""Paper Fig. 6: ME computation cost and leader-selection randomness.

Fig. 6(a): ME time vs network size N × model complexity (MLP hidden width).
Fig. 6(b): leader-selection counts under IID vs non-IID client data
           (randomness/fairness of ME) — run on the full BHFL runtime.

Also benchmarks the Pallas fused-similarity kernel against the 3-pass
reference (the kernel's HBM-traffic claim, DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.model_eval import model_evaluation
from repro.kernels import batched_cosine_similarity
from repro.kernels.ref import cosine_similarity_ref
from repro.models.mlp import MLPConfig

NET_SIZES = [10, 25, 50]
HIDDEN = [64, 128, 256]


def _stacked_models(n: int, hidden: int, seed: int = 0) -> jnp.ndarray:
    cfg = MLPConfig(hidden=hidden)
    d = cfg.in_dim * cfg.hidden + cfg.hidden + cfg.hidden * cfg.n_classes + cfg.n_classes
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def bench_me_cost() -> None:
    """Fig. 6(a)."""
    for hidden in HIDDEN:
        for n in NET_SIZES:
            W = _stacked_models(n, hidden)
            sizes = jnp.ones((n,), jnp.float32)

            def me():
                jax.block_until_ready(model_evaluation(W, sizes))

            us = time_call(me, repeats=5)
            emit(f"me_cost/h{hidden}/N{n}", us, f"D={W.shape[1]}")


def bench_kernel_vs_ref() -> None:
    """Fused Pallas kernel vs 3-pass reference. Interpret mode executes the
    kernel body per grid step in Python, so CPU wall time is advisory only —
    the structural claim is 1 vs 3 HBM passes (small size keeps the
    interpret-mode sweep fast)."""
    W = _stacked_models(8, 64)
    gw = W.mean(0)

    def kern():
        jax.block_until_ready(batched_cosine_similarity(W, gw))

    def ref():
        jax.block_until_ready(cosine_similarity_ref(W, gw))

    us_k = time_call(kern, repeats=5)
    us_r = time_call(ref, repeats=5)
    emit("me_kernel_fused", us_k, "hbm_passes=1")
    emit("me_ref_3pass", us_r, "hbm_passes=3")


def bench_leader_randomness(rounds: int = 12) -> None:
    """Fig. 6(b): leader histogram, IID vs non-IID (label-limited)."""
    from repro.data.synthetic import make_mnist_like
    from repro.fl.hierarchy import build_hierarchy
    from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime

    train, test = make_mnist_like(n_train=1200, n_test=200)
    for dist in ("iid", "label"):
        cfg = BHFLConfig(n_nodes=5, clients_per_node=3, fel_iterations=1)
        clusters = build_hierarchy(train, 5, 3, dist, seed=1)
        rt = BHFLRuntime(clusters, cfg, None)

        import time as _t
        t0 = _t.perf_counter()
        rt.run(rounds)
        us = (_t.perf_counter() - t0) * 1e6 / rounds
        counts = rt.leader_counts()
        # fairness: normalized entropy of the leader histogram (1 = uniform)
        p = np.asarray(list(counts.values()), np.float64)
        p = p / p.sum()
        ent = float(-(p[p > 0] * np.log(p[p > 0])).sum() / np.log(len(p)))
        emit(f"me_randomness/{dist}", us,
             f"entropy={ent:.3f};counts={list(counts.values())}")


def main() -> None:
    bench_me_cost()
    bench_kernel_vs_ref()
    bench_leader_randomness()


if __name__ == "__main__":
    main()
