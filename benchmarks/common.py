"""Shared benchmark helpers: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived carries
the figure-specific value, e.g. a slope or a ratio).
"""

from __future__ import annotations

import time
from typing import Callable


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 1,
              stat: str = "median", **kw) -> float:
    """Wall-time per call in microseconds.

    ``stat="median"`` is the default; ``stat="min"`` records the
    least-contended sample, which is the right estimator for speedup
    ratios on a shared single-core runner where any stray process
    inflates individual samples but never deflates them.
    """
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    if stat == "min":
        return times[0]
    if stat != "median":
        raise ValueError(f"unknown stat {stat!r}")
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
