"""Render the EXPERIMENTS.md §Roofline markdown table from
dryrun_results.json.

Usage: PYTHONPATH=src python -m benchmarks.gen_roofline_table [json] [--mesh 16x16]
"""

from __future__ import annotations

import json
import sys


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    mesh_filter = None
    if "--mesh" in sys.argv:
        mesh_filter = sys.argv[sys.argv.index("--mesh") + 1]
    rows = json.loads(open(path).read())
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    print("| arch | shape | mesh | compute | memory | collective | bound |"
          " useful | dominant-fix hint |")
    print("|---|---|---|---|---|---|---|---|---|")
    hints = {
        "collective": "re-shard to cut sharded-contraction all-reduces",
        "memory": "fuse passes / shrink caches / window reads",
        "compute": "skip masked blocks; MXU-align tiles",
    }
    for (arch, shape, mesh), r in sorted(seen.items()):
        if mesh_filter and mesh != mesh_filter:
            continue
        print(f"| {arch} | {shape} | {mesh} | {fmt_s(r['compute_s'])} "
              f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
              f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
              f"| {hints[r['dominant']]} |")


if __name__ == "__main__":
    main()
