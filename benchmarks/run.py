"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  Fig. 4-5 → bench_hcds            (HCDS commit/reveal cost)
  Fig. 6   → bench_model_eval      (ME cost + leader randomness)
  Fig. 7   → bench_btsv            (BTSV attack resistance)
  Fig. 8   → bench_incentive       (Stackelberg utilities)
  §1 claim → bench_consensus_overhead (energy-recycling quantified)

Roofline rows come from the dry-run (python -m repro.launch.dryrun) since
they need the 512-device XLA flag, which must not leak into this process.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_btsv, bench_consensus_overhead, bench_hcds,
                            bench_incentive, bench_model_eval, bench_roofline)
    print("name,us_per_call,derived")
    mods = [("hcds", bench_hcds), ("model_eval", bench_model_eval),
            ("btsv", bench_btsv), ("incentive", bench_incentive),
            ("consensus_overhead", bench_consensus_overhead),
            ("roofline", bench_roofline)]
    failures = []
    for name, mod in mods:
        try:
            mod.main()
        except Exception as e:  # keep the harness going; report at the end
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
