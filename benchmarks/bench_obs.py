"""Tracer overhead pin: `repro.obs` must be free when off, cheap when on.

Runs the same small-but-complete ``run_bhfl`` task (MLP FEL + five-phase
PoFEL consensus, every instrumented subsystem on the path) twice per
repeat — once under the default :class:`NullRecorder` and once under a
buffering :class:`TraceRecorder` — and compares median wall times. The
pin: full tracing costs < 5% of a round (the instrumentation sits on
``rec.enabled`` fast paths, and span/event emission is list appends).

A warmup run pays the jit compiles for both variants before timing, so
the comparison measures steady-state rounds, not compilation.

CLI::

    PYTHONPATH=src python -m benchmarks.bench_obs --fast \
        --json benchmarks/BENCH_obs.json

Exits non-zero when the overhead pin fails (``--no-check`` disables).
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from pathlib import Path

from benchmarks.common import emit

THRESHOLD = 0.05        # traced may cost at most 5% over untraced

ROUNDS = 3
REPEATS = 5
FAST_ROUNDS = 2
FAST_REPEATS = 3


def _run_once(rec, data, rounds: int, seed: int):
    from repro import api, obs
    with obs.use_recorder(rec):
        t0 = time.perf_counter()
        run = api.run_bhfl(model="mlp", n_nodes=4, clients_per_node=2,
                           fel_iterations=1, rounds=rounds, data=data,
                           seed=seed)
        wall = time.perf_counter() - t0
    return wall, run


def measure(rounds: int = ROUNDS, repeats: int = REPEATS,
            seed: int = 0) -> dict:
    from repro import api, obs
    data = api.make_mnist_like(n_train=400, n_test=120)

    # warmup: trace/compile every jit bucket both paths will touch
    _run_once(obs.NullRecorder(), data, rounds, seed)
    _run_once(obs.TraceRecorder("warmup"), data, rounds, seed)

    null_s, traced_s = [], []
    last_rec = None
    for r in range(repeats):
        wall, _ = _run_once(obs.NullRecorder(), data, rounds, seed)
        null_s.append(wall)
        last_rec = obs.TraceRecorder(f"rep{r}")
        wall, run = _run_once(last_rec, data, rounds, seed)
        traced_s.append(wall)
        assert run.obs is not None   # traced run rolled up its metrics

    null_med = statistics.median(null_s)
    traced_med = statistics.median(traced_s)
    overhead = (traced_med - null_med) / null_med
    return {
        "bench": "obs",
        "seed": seed,
        "rounds": rounds,
        "repeats": repeats,
        "null_median_s": round(null_med, 4),
        "traced_median_s": round(traced_med, 4),
        "overhead_frac": round(overhead, 4),
        "threshold": THRESHOLD,
        "ok": overhead < THRESHOLD,
        "spans_per_run": len(last_rec.spans),
        "events_per_run": len(last_rec.events),
        "counters": last_rec.metrics_snapshot()["counters"],
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="fewer rounds/repeats (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result as JSON (BENCH_obs.json)")
    ap.add_argument("--no-check", action="store_true",
                    help="report only; never fail on the overhead pin")
    args = ap.parse_args()

    rounds = FAST_ROUNDS if args.fast else ROUNDS
    repeats = FAST_REPEATS if args.fast else REPEATS
    res = measure(rounds=rounds, repeats=repeats, seed=args.seed)
    res["fast"] = args.fast

    emit("obs/null_run", res["null_median_s"] * 1e6,
         f"rounds={rounds}")
    emit("obs/traced_run", res["traced_median_s"] * 1e6,
         f"spans={res['spans_per_run']} events={res['events_per_run']}")
    emit("obs/overhead", res["overhead_frac"] * 100.0,
         f"pin<{THRESHOLD * 100:.0f}% ok={res['ok']}")

    if args.json:
        Path(args.json).write_text(json.dumps(res, indent=2) + "\n")
        print(f"wrote {args.json}")

    if not res["ok"] and not args.no_check:
        print(f"FAIL: tracer overhead {res['overhead_frac'] * 100:.1f}% "
              f"exceeds the {THRESHOLD * 100:.0f}% pin")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
