"""Beyond the paper's figures: the central PoFEL claim quantified — the
consensus adds negligible cost on top of FEL training because it recycles
the training computation (paper §1, §4).

Three measurements on the CPU-scale BHFL runtime at paper scale
(N=8 BCFL nodes × 5 clients/node, 3 FEL iterations/round):

* ``fel_engine`` — FEL-phase wall time per BCFL round, reference
  per-client loop vs the batched in-graph engine
  (``repro.fl.batched_fel``), plus their ratio. This is the perf
  trajectory CI tracks (``BENCH_consensus_overhead.json`` artifact).
* ``runtime_split`` — wall-time split of a full round into FEL vs
  consensus (HCDS + ME + BTSV + block) on the batched engine.
* ``analytic`` — for the LLM-scale path, the analytic FLOP overhead of
  the in-graph consensus vs the local FedSGD step (launch/costs.py).

Reading the round-split numbers: see benchmarks/README.md.
"""

from __future__ import annotations

import argparse
import json
import statistics
import time
from typing import Optional

from benchmarks.common import emit

PAPER_N = 8          # BCFL nodes (clusters)
PAPER_CPN = 5        # clients per node
PAPER_ITERS = 3      # FEL iterations per BCFL round (paper §7.1)


def bench_fel_engines(rounds: int = 5, n_train: int = 1200,
                      results: Optional[dict] = None) -> None:
    """FEL-phase wall time per BCFL round: reference loop vs batched
    in-graph engine, identical seeds/hierarchy. Reports the median over
    ``rounds`` timed rounds (first batched round compiles and is
    excluded; the reference path's per-step jits are warmed the same way).
    """
    import jax
    from repro.data.synthetic import make_mnist_like
    from repro.fl.hierarchy import build_hierarchy
    from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime

    train, _ = make_mnist_like(n_train=n_train, n_test=100)

    def runtime(engine: str) -> BHFLRuntime:
        cfg = BHFLConfig(n_nodes=PAPER_N, clients_per_node=PAPER_CPN,
                         fel_iterations=PAPER_ITERS, engine=engine)
        clusters = build_hierarchy(train, PAPER_N, PAPER_CPN, "iid")
        return BHFLRuntime(clusters, cfg, None)

    fel_ms = {}

    rt = runtime("batched")
    t0 = time.perf_counter()
    jax.block_until_ready(rt._engine.run_round(rt._global_flat, 1))
    compile_s = time.perf_counter() - t0
    ts = []
    for r in range(rounds):
        t0 = time.perf_counter()
        jax.block_until_ready(rt._engine.run_round(rt._global_flat, r + 1))
        ts.append(time.perf_counter() - t0)
    fel_ms["batched"] = statistics.median(ts) * 1e3

    rt = runtime("reference")
    rt._run_fel(rt.clusters[0], rt.global_params, 1)   # warm the step jits
    ts = []
    for r in range(rounds):
        t0 = time.perf_counter()
        for c in rt.clusters:
            rt._run_fel(c, rt.global_params, r + 1)
        ts.append(time.perf_counter() - t0)
    fel_ms["reference"] = statistics.median(ts) * 1e3

    speedup = fel_ms["reference"] / fel_ms["batched"]
    emit("consensus_overhead/fel_reference", fel_ms["reference"] * 1e3,
         f"n={PAPER_N} cpn={PAPER_CPN} iters={PAPER_ITERS}")
    emit("consensus_overhead/fel_batched", fel_ms["batched"] * 1e3,
         f"speedup={speedup:.2f}x compile_s={compile_s:.1f}")
    if results is not None:
        results["fel_engine"] = {
            "config": {"n_nodes": PAPER_N, "clients_per_node": PAPER_CPN,
                       "fel_iterations": PAPER_ITERS, "n_train": n_train,
                       "rounds": rounds, "backend": jax.default_backend()},
            "fel_ms": fel_ms,
            "speedup": speedup,
            "batched_compile_s": compile_s,
            "target": {"min_speedup": 5.0, "met": bool(speedup >= 5.0)},
        }


def bench_runtime_split(rounds: int = 3, n_train: int = 1200,
                        results: Optional[dict] = None) -> None:
    """Full-round wall-time split (batched engine): FEL vs everything the
    consensus adds (HCDS commit/reveal, ME, vote tally, block mint)."""
    import jax
    from repro.data.synthetic import make_mnist_like
    from repro.fl.hierarchy import build_hierarchy
    from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime

    train, _ = make_mnist_like(n_train=n_train, n_test=100)
    cfg = BHFLConfig(n_nodes=PAPER_N, clients_per_node=PAPER_CPN,
                     fel_iterations=PAPER_ITERS, engine="batched")
    rt = BHFLRuntime(build_hierarchy(train, PAPER_N, PAPER_CPN, "iid"),
                     cfg, None)
    rt.run_round()                      # compile + warm everything
    fel_t, round_t = [], []
    for _ in range(rounds):
        k = rt.consensus.round
        t0 = time.perf_counter()
        jax.block_until_ready(
            rt._engine.run_round(rt._global_flat, rt.cfg.seed + k + 1))
        t1 = time.perf_counter()
        rt.run_round()                  # the measured FEL re-runs inside
        t2 = time.perf_counter()
        fel_t.append(t1 - t0)
        round_t.append((t2 - t1))
    fel = statistics.median(fel_t)
    full = statistics.median(round_t)
    cons = max(full - fel, 0.0)
    frac = cons / full if full else float("nan")
    emit("consensus_overhead/runtime_full", full / 1 * 1e6,
         f"consensus_fraction={frac:.4f} (pure-Python ECDSA dominates; a C "
         f"library is ~100x faster — see benchmarks/README.md)")
    emit("consensus_overhead/runtime_fel", fel * 1e6,
         f"fel_fraction={1 - frac:.4f}")
    if results is not None:
        results["runtime_split"] = {
            "round_ms": full * 1e3, "fel_ms": fel * 1e3,
            "consensus_ms": cons * 1e3, "consensus_fraction": frac,
        }


def bench_analytic_overhead(results: Optional[dict] = None) -> None:
    """In-graph consensus FLOPs vs local-step FLOPs per PoFEL round."""
    from repro.configs import get_config
    from repro.configs.shapes import INPUT_SHAPES
    from repro.launch.costs import forward_cost
    from repro.models.model_api import Model
    shape = INPUT_SHAPES["train_4k"]
    C = 8
    fractions = {}
    for arch in ("yi-6b", "deepseek-moe-16b", "rwkv6-1.6b"):
        model = Model(get_config(arch))
        fwd = forward_cost(model, shape.global_batch, shape.seq_len)
        train_flops = 4.0 * fwd.flops
        consensus_flops = 8.0 * C * model.n_params() + 2.0 * C * model.n_params()
        frac = consensus_flops / (train_flops + consensus_flops)
        fractions[arch] = frac
        emit(f"consensus_overhead/analytic/{arch}", 0.0, f"fraction={frac:.2e}")
    if results is not None:
        results["analytic_fraction"] = fractions


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=3,
                    help="timed rounds per engine (PR-speed default: 3)")
    ap.add_argument("--n-train", type=int, default=1200,
                    help="synthetic training-set size shared by the "
                         "40 clients")
    ap.add_argument("--json", default="BENCH_consensus_overhead.json",
                    help="result artifact path ('' disables)")
    args = ap.parse_args(argv)

    results: dict = {}
    bench_fel_engines(rounds=args.rounds, n_train=args.n_train,
                      results=results)
    bench_runtime_split(rounds=max(2, args.rounds - 1),
                        n_train=args.n_train, results=results)
    bench_analytic_overhead(results=results)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
