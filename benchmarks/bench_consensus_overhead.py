"""Beyond the paper's figures: the central PoFEL claim quantified — the
consensus adds negligible cost on top of FEL training because it recycles
the training computation (paper §1, §4).

We measure, on the CPU-scale BHFL runtime, the wall-time split of one BCFL
round into (FEL training) vs (PoFEL consensus = HCDS + ME + BTSV + block),
and for the LLM-scale path the analytic FLOP overhead of the in-graph
consensus vs the local FedSGD step (launch/costs.py formulas).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.configs.shapes import INPUT_SHAPES
from repro.data.synthetic import make_mnist_like
from repro.fl.hierarchy import build_hierarchy
from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime
from repro.models.model_api import Model


def bench_runtime_split(rounds: int = 4) -> None:
    train, _ = make_mnist_like(n_train=1200, n_test=100)
    cfg = BHFLConfig(n_nodes=5, clients_per_node=3, fel_iterations=2)
    clusters = build_hierarchy(train, 5, 3, "iid")
    rt = BHFLRuntime(clusters, cfg, None)

    import jax
    from repro.core.model_eval import model_evaluation_pytrees
    from repro.core.btsv import btsv_round, init_history
    import jax.numpy as jnp

    fel_t, cons_t, me_t = 0.0, 0.0, 0.0
    hist = init_history(cfg.n_nodes)
    for _ in range(rounds):
        t0 = time.perf_counter()
        models = [rt._run_fel(c, rt.global_params, round_seed=rt.consensus.round + 1)
                  for c in rt.clusters]
        t1 = time.perf_counter()
        # ME + BTSV alone (the in-graph part of consensus)
        me = model_evaluation_pytrees(models,
                                      [float(c.data_size) for c in rt.clusters])
        votes = jnp.full((cfg.n_nodes,), me.vote)
        P = jnp.broadcast_to(me.predictions, (cfg.n_nodes, cfg.n_nodes))
        res, hist = btsv_round(votes, P, hist)
        jax.block_until_ready(res.leader)
        t_me = time.perf_counter()
        sizes = [float(c.data_size) for c in rt.clusters]
        rec = rt.consensus.run_round(models, sizes)   # full (incl. HCDS/chain)
        from repro.core.serialization import unflatten_pytree
        rt.global_params = unflatten_pytree(rec.global_model, rt.global_params)
        t2 = time.perf_counter()
        fel_t += t1 - t0
        me_t += t_me - t1
        cons_t += t2 - t_me
    frac_full = cons_t / (fel_t + cons_t)
    frac_me = me_t / (fel_t + me_t)
    emit("consensus_overhead/runtime_full", cons_t / rounds * 1e6,
         f"fraction={frac_full:.4f} (pure-Python ECDSA dominates; a C "
         f"library is ~100x faster — see EXPERIMENTS.md)")
    emit("consensus_overhead/runtime_me_btsv", me_t / rounds * 1e6,
         f"fraction={frac_me:.4f}")


def bench_analytic_overhead() -> None:
    """In-graph consensus FLOPs vs local-step FLOPs per PoFEL round."""
    from repro.launch.costs import forward_cost
    shape = INPUT_SHAPES["train_4k"]
    C = 8
    for arch in ("yi-6b", "deepseek-moe-16b", "rwkv6-1.6b"):
        model = Model(get_config(arch))
        fwd = forward_cost(model, shape.global_batch, shape.seq_len)
        train_flops = 4.0 * fwd.flops
        consensus_flops = 8.0 * C * model.n_params() + 2.0 * C * model.n_params()
        emit(f"consensus_overhead/analytic/{arch}", 0.0,
             f"fraction={consensus_flops / (train_flops + consensus_flops):.2e}")


def main() -> None:
    bench_runtime_split()
    bench_analytic_overhead()


if __name__ == "__main__":
    main()
