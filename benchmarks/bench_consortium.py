"""Consortium sharding sweep: round wall-time vs committee count at fixed N.

The single-committee PoFEL pipeline broadcasts every envelope to every
node, so per-round work grows ~N² and realistic scale caps near N≈32.
Sharding the consortium into K committee-scoped instances
(``repro.fl.consortium``) bounds each shard's fan-out by its own size
(~N/K), with a K-block cross-shard checkpoint epoch as the stitching
cost. This sweep runs the full BHFL pipeline at fixed N over
K ∈ {1, 2, 4, 8} and records each cell's wall-time per round — the
sharding claim is the headline ratio round(K=1) / round(K=max).

K=1 runs the pre-shard single-committee path (``committees=1`` is the
bench baseline the equivalence test pins), so the comparison is against
exactly the code the consortium replaced, on the same scenario sizing.

CLI::

    PYTHONPATH=src python -m benchmarks.bench_consortium \
        --json benchmarks/BENCH_consortium.json        # N=256, K=1,2,4,8
    PYTHONPATH=src python -m benchmarks.bench_consortium --fast  # N=64
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Optional

from benchmarks.common import emit

FULL_N = 256
FULL_KS = (1, 2, 4, 8)
FAST_N = 64
FAST_KS = (1, 4)
ROUNDS = 2


def run_cell(n_nodes: int, k: int, rounds: int = ROUNDS,
             seed: int = 0) -> dict:
    """One full BHFL run (FEL + consensus + checkpoints) at committees=K."""
    from repro import api
    from repro.sim import Scenario

    scenario = Scenario(
        name=f"bench_consortium_n{n_nodes}_k{k}",
        description=f"consortium sweep cell N={n_nodes} K={k}",
        rounds=rounds, n_nodes=n_nodes, clients_per_node=1,
        committees=k, checkpoint_interval=2,
        n_train=512, n_test=64)
    t0 = time.perf_counter()
    run = api.run_bhfl(scenario=scenario, seed=seed)
    wall_s = time.perf_counter() - t0
    rep = run.scenario_report
    return {
        "n_nodes": n_nodes,
        "committees": k,
        "rounds": rounds,
        "seed": seed,
        "wall_s": round(wall_s, 3),
        "round_wall_s": round(wall_s / rounds, 3),
        "liveness": rep.liveness,
        "completed_rounds": rep.completed_rounds,
        "safety_violations": rep.safety_violations,
        "converged": rep.converged,
        "top_chain_converged": rep.top_chain_converged,
        "cross_shard_checkpoints": rep.cross_shard_checkpoints,
    }


def sweep(fast: bool = False, seed: int = 0) -> dict:
    n_nodes = FAST_N if fast else FULL_N
    ks = FAST_KS if fast else FULL_KS
    cells = []
    for k in ks:
        cell = run_cell(n_nodes, k, seed=seed)
        cells.append(cell)
        emit(f"consortium[N={n_nodes},K={k}]",
             cell["round_wall_s"] * 1e6,
             f"liveness={cell['liveness']},"
             f"safety={cell['safety_violations']},"
             f"checkpoints={cell['cross_shard_checkpoints']}")

    # the headline claim, stated in the artifact: at fixed N, sharding
    # into K committees cuts round wall-time vs the single committee
    base = cells[0]
    sharded = cells[-1]
    headline = {
        "n_nodes": n_nodes,
        "k_base": base["committees"],
        "k_sharded": sharded["committees"],
        "round_wall_s_k1": base["round_wall_s"],
        "round_wall_s_sharded": sharded["round_wall_s"],
        "speedup": round(base["round_wall_s"]
                         / max(sharded["round_wall_s"], 1e-9), 2),
    }
    return {"bench": "consortium", "seed": seed, "fast": fast,
            "cells": cells, "headline": headline}


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help=f"CI subset: N={FAST_N}, K={FAST_KS}")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the sweep to this JSON file "
                         "(BENCH_consortium.json)")
    args = ap.parse_args(argv)
    results = sweep(fast=args.fast, seed=args.seed)
    bad = [c for c in results["cells"]
           if not c["liveness"] or c["safety_violations"]]
    if bad:
        raise SystemExit(f"benchmark cells lost liveness/safety: {bad}")
    h = results["headline"]
    if h["speedup"] <= 1.0:
        raise SystemExit(
            f"sharding did not reduce round wall-time at N={h['n_nodes']}: "
            f"K={h['k_base']} {h['round_wall_s_k1']}s vs "
            f"K={h['k_sharded']} {h['round_wall_s_sharded']}s")
    print(f"headline: N={h['n_nodes']} round wall-time "
          f"{h['round_wall_s_k1']}s (K={h['k_base']}) -> "
          f"{h['round_wall_s_sharded']}s (K={h['k_sharded']}), "
          f"speedup {h['speedup']}x")
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
