"""Reliability sweep: liveness vs link loss, with and without the retry bus.

The PoFEL deployment story (paper §3.1) assumes BCFL nodes on a WAN —
where 10–40% per-message loss is a configuration, not a catastrophe. This
sweep runs the full BHFL round pipeline over ``drop_rate x max_retries``
and records, per cell, whether every round minted a block (liveness), how
many rounds aborted on ``QuorumNotReached``, and what the retransmission
layer paid for the rescue (resends, recovered deliveries, gossip pulls).

The headline row (pinned by ``tests/test_reliability.py``): at
``drop_rate=0.4`` the one-shot bus (``max_retries=0``) cannot hold
commit/reveal quorum and aborts rounds, while 3 bounded-backoff
retransmissions inside the same phase deadlines restore full liveness.

CLI::

    PYTHONPATH=src python -m benchmarks.bench_reliability --fast \
        --json benchmarks/BENCH_reliability.json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Optional

from benchmarks.common import emit

DROPS = (0.1, 0.25, 0.4)
RETRIES = (0, 3)
FAST_DROPS = (0.4,)


def run_cell(drop_rate: float, max_retries: int, gossip: bool = False,
             rounds: int = 4, seed: int = 0) -> dict:
    """One full BHFL run on a lossy WAN; returns the cell's verdict."""
    from repro.sim import runner as sim_runner
    from repro.sim.network import LinkSpec, NetworkConfig, RetrySpec
    from repro.sim.scenarios import Scenario

    tag = (f"bench_d{int(drop_rate * 100)}_r{max_retries}"
           + ("_g" if gossip else ""))
    scenario = Scenario(
        name=tag,
        description=f"reliability sweep cell drop={drop_rate} "
                    f"retries={max_retries} gossip={gossip}",
        rounds=rounds, n_nodes=6,
        net=NetworkConfig(
            link=LinkSpec(5.0, 4.0, drop_rate=drop_rate),
            retry=RetrySpec(max_retries=max_retries, base_backoff=4.0,
                            backoff_factor=2.0, gossip=gossip)))
    t0 = time.perf_counter()
    report = sim_runner.run_scenario(scenario, seed=seed)
    wall_s = time.perf_counter() - t0
    quorum_aborts = sum(1 for e in report.events
                        if e.get("event") == "round_aborted"
                        and "quorum" in str(e.get("reason", "")).lower())
    return {
        "drop_rate": drop_rate,
        "max_retries": max_retries,
        "gossip": gossip,
        "seed": seed,
        "rounds": rounds,
        "liveness": report.liveness,
        "completed_rounds": report.completed_rounds,
        "aborted_rounds": report.aborted_rounds,
        "quorum_aborts": quorum_aborts,
        "safety_violations": report.safety_violations,
        "retransmits": report.retransmits,
        "recovered_deliveries": report.recovered_deliveries,
        "gossip_deliveries": report.gossip_deliveries,
        "wall_s": round(wall_s, 3),
    }


def sweep(fast: bool = False, seed: int = 0) -> dict:
    drops = FAST_DROPS if fast else DROPS
    cells = []
    for drop in drops:
        for retries in RETRIES:
            cell = run_cell(drop, retries, seed=seed)
            cells.append(cell)
            emit(f"reliability[drop={drop},retries={retries}]",
                 cell["wall_s"] * 1e6,
                 f"liveness={cell['liveness']},"
                 f"aborted={cell['aborted_rounds']},"
                 f"retransmits={cell['retransmits']}")
        # gossip variant at the max retry budget only — the anti-entropy
        # pass is the marginal rescue on top of retransmission
        cell = run_cell(drop, max(RETRIES), gossip=True, seed=seed)
        cells.append(cell)
        emit(f"reliability[drop={drop},retries={max(RETRIES)},gossip]",
             cell["wall_s"] * 1e6,
             f"liveness={cell['liveness']},"
             f"gossip_deliveries={cell['gossip_deliveries']}")

    # the headline claim, stated in the artifact itself so the JSON is
    # self-describing: a drop rate where retries flip abort -> liveness
    headline = None
    by_key = {(c["drop_rate"], c["max_retries"], c["gossip"]): c
              for c in cells}
    for drop in drops:
        one_shot = by_key.get((drop, 0, False))
        retried = by_key.get((drop, max(RETRIES), False))
        if (one_shot and retried and retried["liveness"]
                and one_shot["quorum_aborts"] > 0):
            headline = {
                "drop_rate": drop,
                "one_shot_quorum_aborts": one_shot["quorum_aborts"],
                "retry_liveness": retried["liveness"],
                "max_retries": retried["max_retries"],
            }
    return {"bench": "reliability", "seed": seed, "fast": fast,
            "cells": cells, "headline": headline}


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: drop_rate=0.4 only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the sweep to this JSON file "
                         "(BENCH_reliability.json)")
    args = ap.parse_args(argv)
    results = sweep(fast=args.fast, seed=args.seed)
    if results["headline"] is None:
        raise SystemExit("no drop rate flipped abort -> liveness; "
                         "the retry-bus claim did not reproduce")
    h = results["headline"]
    print(f"headline: drop_rate={h['drop_rate']} one-shot aborts "
          f"{h['one_shot_quorum_aborts']} round(s) on QuorumNotReached; "
          f"max_retries={h['max_retries']} restores liveness")
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
