"""Paper Figs. 4-5: HCDS Commit/Reveal computation cost.

Fig. 4(a): H + DSign cost vs (random nonce length × model complexity)
Fig. 4(b): DVerify cost vs network size N
Fig. 5(a): Reveal cost vs (N × nonce length)
Fig. 5(b): Reveal cost vs (N × model complexity)

Model complexity is swept exactly as in the paper: the MLP hidden layer
width (§7.2, "we change the number of neurons in the hidden layer").

Beyond-paper: ``bench_round_verify_sweep`` times one round's worth of
signature verification — the N×(N−1) commit-envelope checks every PoFEL
round performs — under each crypto backend (``naive`` double-and-add,
``windowed`` per-message tables, ``batch`` dedup + randomized-linear-
combination), and ``--json`` records the sweep as
``benchmarks/BENCH_hcds.json`` so the crypto wall-time trajectory
accumulates per PR next to ``BENCH_consensus_overhead.json``.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import crypto
from repro.core.envelope import SignedEnvelope
from repro.core.hcds import HCDSNode
from repro.core.serialization import serialize_pytree
from repro.models.mlp import MLPConfig, mlp_init

NONCE_LENS = [16, 64, 256, 1024]
HIDDEN = [64, 128, 256]
NET_SIZES = [10, 25, 50]
ROUND_SIZES = [4, 8, 16, 32]    # N for the round-level verify sweep
NAIVE_MAX_N = 8                 # double-and-add at N=32 would take minutes
MIN_BATCH_SPEEDUP_AT_16 = 3.0   # acceptance bar: batch vs windowed, N=16


def _model(hidden: int):
    return mlp_init(MLPConfig(hidden=hidden), jax.random.key(0))


def bench_commit_stage() -> None:
    """Fig. 4(a): time of H(r‖w) + DSign vs nonce length and model size."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    for hidden in HIDDEN:
        model_bytes = serialize_pytree(_model(hidden))
        for nlen in NONCE_LENS:
            nonce = crypto.random_nonce(nlen)

            def commit():
                d = crypto.sha256_digest(nonce, model_bytes)
                crypto.dsign(d, kp.private_key)

            us = time_call(commit, repeats=5)
            emit(f"hcds_commit/h{hidden}/nonce{nlen}", us,
                 f"model_bytes={len(model_bytes)}")


def bench_dverify_vs_network() -> None:
    """Fig. 4(b): DVerify cost grows linearly with N."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    d = crypto.sha256_digest(b"digest")
    tag = crypto.dsign(d, kp.private_key)
    for n in NET_SIZES:
        def verify_all():
            for _ in range(n - 1):
                assert crypto.dverify(tag, kp.public_key, d)

        us = time_call(verify_all, repeats=3)
        emit(f"hcds_commit_verify/N{n}", us, f"per_node={us/(n-1):.1f}us")


def bench_reveal_stage() -> None:
    """Fig. 5: Reveal = hash recompute + DVerify per peer, vs N and model."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    for hidden in [64, 256]:
        model_bytes = serialize_pytree(_model(hidden))
        nonce = crypto.random_nonce(32)
        d = crypto.sha256_digest(nonce, model_bytes)
        tag = crypto.dsign(d, kp.private_key)
        for n in NET_SIZES:
            def reveal_all():
                for _ in range(n - 1):
                    dd = crypto.sha256_digest(nonce, model_bytes)
                    assert dd == d
                    assert crypto.dverify(tag, kp.public_key, dd)

            us = time_call(reveal_all, repeats=3)
            emit(f"hcds_reveal/h{hidden}/N{n}", us, f"per_node={us/(n-1):.1f}us")


def bench_scalar_mul_backends() -> None:
    """Before/after for the windowed-table optimization (ROADMAP: pure-Python
    ECDSA dominates the consensus share of a round).

    * ``naive``    — double-and-add, the pre-optimization baseline;
    * ``windowed`` — 4-bit fixed-window table (the live path for the base
      point and, via the per-key cache, for repeated verifies);
    * ``verify_cold/warm`` — DVerify with an empty vs populated public-key
      table cache (one consensus round re-verifies each key O(N) times, so
      the warm number is the steady-state cost).
    """
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    k = kp.private_key
    us = time_call(lambda: crypto._point_mul_naive(k, (crypto._GX, crypto._GY)),
                   repeats=10)
    emit("ecdsa_point_mul/naive", us)
    table = crypto._g_table()
    us_w = time_call(lambda: crypto._point_mul_windowed(k, table), repeats=10)
    emit("ecdsa_point_mul/windowed", us_w, f"speedup={us/us_w:.1f}x")

    d = crypto.sha256_digest(b"digest")
    tag = crypto.dsign(d, k)

    def verify_cold():
        crypto._PK_TABLES.clear()
        assert crypto.dverify(tag, kp.public_key, d)

    us_cold = time_call(verify_cold, repeats=5)
    emit("ecdsa_verify/cold_cache", us_cold)
    assert crypto.dverify(tag, kp.public_key, d)  # populate the cache
    us_warm = time_call(lambda: crypto.dverify(tag, kp.public_key, d),
                        repeats=10)
    emit("ecdsa_verify/warm_cache", us_warm, f"speedup={us_cold/us_warm:.1f}x")


def bench_round_verify_sweep(results: Optional[dict] = None) -> dict:
    """Round-level verification cost per backend at N∈{4,8,16,32}.

    The workload is exactly what one PoFEL round pays in the commit phase:
    every one of N receivers checks the other N−1 senders' commit
    envelopes — N×(N−1) (tag, PK, digest) verifications. The per-message
    backends (``naive``, ``windowed``) pay each check individually; the
    ``batch`` backend hands the same N×(N−1) item list to ``verify_batch``,
    which dedups the receiver copies to N distinct tags and folds them into
    one randomized-linear-combination equation. The acceptance bar
    (``target``) is ≥3× batch-over-windowed at N=16.
    """
    sweep: dict = {}
    for n in ROUND_SIZES:
        kps = [crypto.ECDSAKeyPair.generate(b"rv" + bytes([i]))
               for i in range(n)]
        envs = [SignedEnvelope.seal(
            "commit", 0, i, crypto.sha256_digest(b"model", bytes([i])),
            kps[i].private_key) for i in range(n)]
        # one item per (receiver, sender) pair — the round's real workload
        items = [(envs[s].signature, kps[s].public_key,
                  envs[s].signing_digest())
                 for r in range(n) for s in range(n) if s != r]
        row: dict = {"n_nodes": n, "verifications": len(items)}

        def per_message(backend):
            def run():
                res = crypto.verify_batch(items, backend=backend)
                assert res.ok
            return run

        if n <= NAIVE_MAX_N:
            row["naive_us"] = time_call(per_message("naive"), repeats=1,
                                        warmup=0)
            emit(f"hcds_round_verify/naive/N{n}", row["naive_us"])
        row["windowed_us"] = time_call(per_message("windowed"), repeats=3)
        emit(f"hcds_round_verify/windowed/N{n}", row["windowed_us"])
        row["batch_us"] = time_call(per_message("batch"), repeats=3)
        row["batch_speedup_vs_windowed"] = (row["windowed_us"]
                                            / row["batch_us"])
        emit(f"hcds_round_verify/batch/N{n}", row["batch_us"],
             f"speedup_vs_windowed={row['batch_speedup_vs_windowed']:.1f}x")
        sweep[f"N{n}"] = row
    out = {
        "round_verify": sweep,
        "target": {"min_batch_speedup_vs_windowed_at_N16":
                   MIN_BATCH_SPEEDUP_AT_16,
                   "measured_at_N16":
                   sweep["N16"]["batch_speedup_vs_windowed"]},
    }
    if results is not None:
        results.update(out)
    return out


def bench_full_round_protocol() -> None:
    """End-to-end HCDS round among N in-process nodes (beyond-paper)."""
    from repro.core.hcds import run_hcds_round
    for n in [5, 10]:
        nodes = [HCDSNode(i) for i in range(n)]
        models = [_model(64) for _ in range(n)]

        def round_():
            run_hcds_round(nodes, models, round=np.random.randint(1 << 30))

        us = time_call(round_, repeats=2, warmup=0)
        emit(f"hcds_full_round/N{n}", us, f"msgs={n*(n-1)*2}")


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="HCDS commit/reveal + crypto-backend benchmarks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the round-verify sweep (naive/windowed/"
                         "batch) to this JSON file (BENCH_hcds.json)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="run only the round-level verify sweep")
    args = ap.parse_args(argv)
    if not args.sweep_only:
        bench_commit_stage()
        bench_dverify_vs_network()
        bench_reveal_stage()
        bench_scalar_mul_backends()
        bench_full_round_protocol()
    results: dict = {}
    bench_round_verify_sweep(results)
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
