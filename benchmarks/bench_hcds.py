"""Paper Figs. 4-5: HCDS Commit/Reveal computation cost.

Fig. 4(a): H + DSign cost vs (random nonce length × model complexity)
Fig. 4(b): DVerify cost vs network size N
Fig. 5(a): Reveal cost vs (N × nonce length)
Fig. 5(b): Reveal cost vs (N × model complexity)

Model complexity is swept exactly as in the paper: the MLP hidden layer
width (§7.2, "we change the number of neurons in the hidden layer").

Beyond-paper: ``bench_round_verify_sweep`` times one round's worth of
signature verification — the N×(N−1) commit-envelope checks every PoFEL
round performs — under each crypto backend (``naive`` double-and-add,
``windowed`` per-message tables, ``batch`` dedup + randomized-linear-
combination), and ``--json`` records the sweep as
``benchmarks/BENCH_hcds.json`` so the crypto wall-time trajectory
accumulates per PR next to ``BENCH_consensus_overhead.json``.

``bench_crypto_backend_sweep`` (``--crypto-json``, recorded as
``benchmarks/BENCH_crypto.json``) is the point-arithmetic sweep for the
GLV/Pippenger rework: batches of N ∈ {4, 8, 16, 32, 64, 256} distinct
signatures through every backend (windowed / batch / glv / jax, naive at
small N), measured against TWO in-process reconstructions so the
speedups are apples-to-apples on the machine that ran the sweep:

* ``pr4_affine_batch`` — PR 4's affine RLC path (one modular inversion
  per point add);
* ``pr5_batch`` — PR 5's Jacobian batch path (fixed-window ladders +
  Strauss–Shamir), i.e. the *previous* default backend, rebuilt verbatim
  from the unchanged ``curve`` primitives it used.

The sweep also records the AOT kernel-cache split (cold trace+compile vs
warm blob load, per pow2 lane bucket, each measured in a fresh
subprocess) and the ``set_backend("auto")`` calibration probe. The
acceptance bars: default backend ≥2× over the PR-5 batch at N=32, and a
jax warm start (AOT hit, fresh process) under 1 s.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from collections import OrderedDict

from benchmarks.common import emit, time_call
from repro.core import crypto
from repro.core.crypto import curve
from repro.core.envelope import SignedEnvelope
from repro.core.hcds import HCDSNode
from repro.core.serialization import serialize_pytree
from repro.models.mlp import MLPConfig, mlp_init

NONCE_LENS = [16, 64, 256, 1024]
HIDDEN = [64, 128, 256]
NET_SIZES = [10, 25, 50]
ROUND_SIZES = [4, 8, 16, 32]    # N for the round-level verify sweep
CRYPTO_BATCH_SIZES = [4, 8, 16, 32, 64, 256]  # signatures per verify_batch
NAIVE_MAX_N = 8                 # double-and-add at N=32 would take minutes
MIN_BATCH_SPEEDUP_AT_16 = 3.0   # acceptance bar: batch vs windowed, N=16
# acceptance bar carried over from the Jacobian/JAX PR: default backend
# vs PR-4's affine batch path at N=16
MIN_DEFAULT_SPEEDUP_VS_PR4_AT_16 = 2.5
# acceptance bars for the GLV/Pippenger PR: default backend vs PR-5's
# Jacobian batch path at N=32, and the AOT warm start (fresh process,
# serialized-kernel hit) at the 16-lane bucket
MIN_BATCH_SPEEDUP_VS_PR5_AT_32 = 2.0
MAX_JAX_WARM_START_S = 1.0


def _model(hidden: int):
    return mlp_init(MLPConfig(hidden=hidden), jax.random.key(0))


def bench_commit_stage() -> None:
    """Fig. 4(a): time of H(r‖w) + DSign vs nonce length and model size."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    for hidden in HIDDEN:
        model_bytes = serialize_pytree(_model(hidden))
        for nlen in NONCE_LENS:
            nonce = crypto.random_nonce(nlen)

            def commit():
                d = crypto.sha256_digest(nonce, model_bytes)
                crypto.dsign(d, kp.private_key)

            us = time_call(commit, repeats=5)
            emit(f"hcds_commit/h{hidden}/nonce{nlen}", us,
                 f"model_bytes={len(model_bytes)}")


def bench_dverify_vs_network() -> None:
    """Fig. 4(b): DVerify cost grows linearly with N."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    d = crypto.sha256_digest(b"digest")
    tag = crypto.dsign(d, kp.private_key)
    for n in NET_SIZES:
        def verify_all():
            for _ in range(n - 1):
                assert crypto.dverify(tag, kp.public_key, d)

        us = time_call(verify_all, repeats=3)
        emit(f"hcds_commit_verify/N{n}", us, f"per_node={us/(n-1):.1f}us")


def bench_reveal_stage() -> None:
    """Fig. 5: Reveal = hash recompute + DVerify per peer, vs N and model."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    for hidden in [64, 256]:
        model_bytes = serialize_pytree(_model(hidden))
        nonce = crypto.random_nonce(32)
        d = crypto.sha256_digest(nonce, model_bytes)
        tag = crypto.dsign(d, kp.private_key)
        for n in NET_SIZES:
            def reveal_all():
                for _ in range(n - 1):
                    dd = crypto.sha256_digest(nonce, model_bytes)
                    assert dd == d
                    assert crypto.dverify(tag, kp.public_key, dd)

            us = time_call(reveal_all, repeats=3)
            emit(f"hcds_reveal/h{hidden}/N{n}", us, f"per_node={us/(n-1):.1f}us")


def bench_scalar_mul_backends() -> None:
    """Before/after for the windowed-table optimization (ROADMAP: pure-Python
    ECDSA dominates the consensus share of a round).

    * ``naive``    — double-and-add, the pre-optimization baseline;
    * ``windowed`` — 4-bit fixed-window table (the live path for the base
      point and, via the per-key cache, for repeated verifies);
    * ``verify_cold/warm`` — DVerify with an empty vs populated public-key
      table cache (one consensus round re-verifies each key O(N) times, so
      the warm number is the steady-state cost).
    """
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    k = kp.private_key
    us = time_call(lambda: crypto._point_mul_naive(k, (crypto._GX, crypto._GY)),
                   repeats=10)
    emit("ecdsa_point_mul/naive", us)
    table = crypto._g_table()
    us_w = time_call(lambda: crypto._point_mul_windowed(k, table), repeats=10)
    emit("ecdsa_point_mul/windowed", us_w, f"speedup={us/us_w:.1f}x")

    d = crypto.sha256_digest(b"digest")
    tag = crypto.dsign(d, k)

    def verify_cold():
        crypto._PK_TABLES.clear()
        assert crypto.dverify(tag, kp.public_key, d)

    us_cold = time_call(verify_cold, repeats=5)
    emit("ecdsa_verify/cold_cache", us_cold)
    assert crypto.dverify(tag, kp.public_key, d)  # populate the cache
    us_warm = time_call(lambda: crypto.dverify(tag, kp.public_key, d),
                        repeats=10)
    emit("ecdsa_verify/warm_cache", us_warm, f"speedup={us_cold/us_warm:.1f}x")


def bench_round_verify_sweep(results: Optional[dict] = None) -> dict:
    """Round-level verification cost per backend at N∈{4,8,16,32}.

    The workload is exactly what one PoFEL round pays in the commit phase:
    every one of N receivers checks the other N−1 senders' commit
    envelopes — N×(N−1) (tag, PK, digest) verifications. The per-message
    backends (``naive``, ``windowed``) pay each check individually; the
    ``batch`` backend hands the same N×(N−1) item list to ``verify_batch``,
    which dedups the receiver copies to N distinct tags and folds them into
    one randomized-linear-combination equation. The acceptance bar
    (``target``) is ≥3× batch-over-windowed at N=16.
    """
    sweep: dict = {}
    for n in ROUND_SIZES:
        kps = [crypto.ECDSAKeyPair.generate(b"rv" + bytes([i]))
               for i in range(n)]
        envs = [SignedEnvelope.seal(
            "commit", 0, i, crypto.sha256_digest(b"model", bytes([i])),
            kps[i].private_key) for i in range(n)]
        # one item per (receiver, sender) pair — the round's real workload
        items = [(envs[s].signature, kps[s].public_key,
                  envs[s].signing_digest())
                 for r in range(n) for s in range(n) if s != r]
        row: dict = {"n_nodes": n, "verifications": len(items)}

        def per_message(backend):
            def run():
                if not crypto.verify_batch(items, backend=backend).ok:
                    raise RuntimeError(
                        f"backend {backend!r} rejected a valid batch")
            return run

        if n <= NAIVE_MAX_N:
            row["naive_us"] = time_call(per_message("naive"), repeats=1,
                                        warmup=0)
            emit(f"hcds_round_verify/naive/N{n}", row["naive_us"])
        row["windowed_us"] = time_call(per_message("windowed"), repeats=3)
        emit(f"hcds_round_verify/windowed/N{n}", row["windowed_us"])
        row["batch_us"] = time_call(per_message("batch"), repeats=3)
        row["batch_speedup_vs_windowed"] = (row["windowed_us"]
                                            / row["batch_us"])
        emit(f"hcds_round_verify/batch/N{n}", row["batch_us"],
             f"speedup_vs_windowed={row['batch_speedup_vs_windowed']:.1f}x")
        sweep[f"N{n}"] = row
    out = {
        "round_verify": sweep,
        "target": {"min_batch_speedup_vs_windowed_at_N16":
                   MIN_BATCH_SPEEDUP_AT_16,
                   "measured_at_N16":
                   sweep["N16"]["batch_speedup_vs_windowed"]},
    }
    if results is not None:
        results.update(out)
    return out


def _round_items(n: int):
    """One round's verification workload: every one of N receivers checks
    the other N−1 senders' commit envelopes."""
    kps = [crypto.ECDSAKeyPair.generate(b"cb" + bytes([i])) for i in range(n)]
    envs = [SignedEnvelope.seal(
        "commit", 0, i, crypto.sha256_digest(b"model", bytes([i])),
        kps[i].private_key) for i in range(n)]
    return [(envs[s].signature, kps[s].public_key, envs[s].signing_digest())
            for r in range(n) for s in range(n) if s != r]


def _pr4_affine_verify_batch(items) -> bool:
    """PR 4's ``batch`` path, reconstructed from the affine baseline ops
    (``curve.affine_*``): dedup + ONE randomized-linear-combination
    equation where every point add pays a modular inversion. Timed in the
    same process as the Jacobian/JAX backends so the recorded speedups are
    hardware-independent ratios, not cross-machine folklore."""
    distinct: "OrderedDict[tuple, None]" = OrderedDict()
    for tag, pk, d in items:
        distinct.setdefault((tuple(tag), pk, d), None)
    sg = 0
    acc = curve.INF
    r_terms = []
    for (tag, pk, d) in distinct:
        sig = crypto.Signature(*tag)
        R = crypto._recover_R(sig)
        assert R is not None
        w = crypto._inv_mod(sig.s, crypto._N)
        a = crypto._rlc_coefficient()
        sg = (sg + a * (crypto._bits2int(d) * w % crypto._N)) % crypto._N
        u2 = sig.r * w % crypto._N
        acc = curve.affine_point_add(
            acc, curve.affine_point_mul_windowed(a * u2 % crypto._N,
                                                 curve.pk_table(pk)))
        r_terms.append((a, (R[0], (-R[1]) % crypto._P)))
    acc = curve.affine_point_add(
        acc, curve.affine_point_mul_windowed(sg, curve.g_table()))
    acc = curve.affine_point_add(acc, curve.affine_multi_scalar(r_terms))
    return curve.is_inf(acc)


def _sweep_items(n: int):
    """N distinct (tag, PK, digest) triples — the post-dedup batch shape
    ``verify_batch`` folds into one RLC equation. (The round sweep above
    covers the pre-dedup N×(N−1) receiver-copy workload.)"""
    items = []
    for i in range(n):
        kp = crypto.ECDSAKeyPair.generate(b"cs" + i.to_bytes(2, "big"))
        d = crypto.sha256_digest(b"sweep", i.to_bytes(2, "big"))
        items.append((crypto.dsign(d, kp.private_key), kp.public_key, d))
    return items


def _pr5_batch_verify(items) -> bool:
    """PR 5's ``batch`` path — the previous default backend — rebuilt
    verbatim from the (unchanged) curve primitives it used: one
    fixed-window Jacobian ladder per key plus Strauss–Shamir for the R
    terms, one point-mul per batch item. The GLV+Pippenger headline bar
    (``MIN_BATCH_SPEEDUP_VS_PR5_AT_32``) measures against this."""
    distinct: "OrderedDict[tuple, None]" = OrderedDict()
    for tag, pk, d in items:
        distinct.setdefault((tuple(tag), pk, d), None)
    sg = 0
    acc = curve.J_INF
    r_terms = []
    for (tag, pk, d) in distinct:
        sig = crypto.Signature(*tag)
        R = crypto._recover_R(sig)
        assert R is not None
        w = crypto._inv_mod(sig.s, crypto._N)
        a = crypto._rlc_coefficient()
        sg = (sg + a * (crypto._bits2int(d) * w % crypto._N)) % crypto._N
        u2 = sig.r * w % crypto._N
        acc = curve.jc_add(acc, curve.point_mul_windowed_jc(
            a * u2 % crypto._N, curve.pk_table(pk)))
        r_terms.append((a, (R[0], (-R[1]) % crypto._P)))
    acc = curve.jc_add(acc,
                       curve.point_mul_windowed_jc(sg, curve.g_table()))
    acc = curve.jc_add(acc, curve.multi_scalar_jc(r_terms))
    return curve.jc_is_inf(acc)


def _aot_cache_split(lanes) -> dict:
    """Cold vs warm kernel start-up per pow2 lane bucket, each side in a
    FRESH subprocess (in-process timing would hit jit/export caches):

    * ``cold`` — ``aotcache --warm``: trace + export + XLA compile where
      no blob exists yet; a bucket already on disk reports
      ``source: "aot"`` instead of a compile (its cold cost was paid on
      an earlier run).
    * ``warm`` — ``aotcache --smoke``: blob deserialize + persistent-XLA-
      cache hit — the start-up every later process actually pays.
    """
    arg = ",".join(str(x) for x in lanes)
    out: dict = {}
    for label, flag in (("cold", "--warm"), ("warm", "--smoke")):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.core.crypto.aotcache",
             flag, "--lanes", arg], capture_output=True, text=True)
        try:
            report = json.loads(proc.stdout)
        except json.JSONDecodeError:
            out[label] = {"error": proc.stderr[-500:]}
            continue
        out[label] = {f"l{b.get('lanes', '?')}": b
                      for b in report["buckets"]}
        for key, b in out[label].items():
            if "first_call_s" in b:
                emit(f"crypto_aot/{label}/{key}",
                     b["first_call_s"] * 1e6, f"source={b['source']}")
    return out


def bench_crypto_backend_sweep(results: Optional[dict] = None) -> dict:
    """Point-arithmetic backend sweep (BENCH_crypto.json).

    ``verify_batch`` cost per backend over batches of N distinct
    signatures, against the in-process PR-4 (affine) and PR-5 (Jacobian
    fixed-window) reconstructions. ``jax`` rows are steady-state (the
    lane bucket's kernel warmed first); the cold-vs-warm start-up split
    lives under ``aot``, measured in fresh subprocesses, and the
    ``set_backend("auto")`` probe under ``calibration``.
    """
    try:
        crypto._get_ops("jax")
        have_jax = True
    except Exception as e:          # jax-less installs still get the sweep
        have_jax = False
        emit("crypto_backends/jax", 0.0, f"unavailable: {e}")
    aot = _aot_cache_split(CRYPTO_BATCH_SIZES) if have_jax else {}
    sweep: dict = {}
    for n in CRYPTO_BATCH_SIZES:
        items = _sweep_items(n)
        row: dict = {"batch_size": n}

        def run_backend(backend):
            # explicit raise, not assert: the timed workload must survive
            # `python -O`, and a backend wrongly rejecting the valid batch
            # must poison the sweep instead of the recorded numbers
            def run():
                if not crypto.verify_batch(items, backend=backend).ok:
                    raise RuntimeError(
                        f"backend {backend!r} rejected a valid batch")
            return run

        def run_recon(name, fn):
            def run():
                if not fn(items):
                    raise RuntimeError(
                        f"{name} reconstruction rejected a valid batch")
            return run

        if n <= NAIVE_MAX_N:
            row["naive_us"] = time_call(run_backend("naive"), repeats=1,
                                        warmup=1)
            emit(f"crypto_backends/naive/N{n}", row["naive_us"])
        # the whole sweep records min-of-N, not the median: the bench
        # runner is a single shared core, so contention only ever
        # inflates samples — the fastest rep is the honest steady-state
        # cost and keeps the pinned headline ratio out of scheduler noise
        row["windowed_us"] = time_call(run_backend("windowed"), repeats=3,
                                       stat="min")
        emit(f"crypto_backends/windowed/N{n}", row["windowed_us"])
        row["pr4_affine_batch_us"] = time_call(
            run_recon("pr4", _pr4_affine_verify_batch), repeats=3,
            stat="min")
        emit(f"crypto_backends/pr4_affine_batch/N{n}",
             row["pr4_affine_batch_us"])
        # the headline ratio lives on these two rows — extra repeats
        row["pr5_batch_us"] = time_call(
            run_recon("pr5", _pr5_batch_verify), repeats=7, stat="min")
        emit(f"crypto_backends/pr5_batch/N{n}", row["pr5_batch_us"])
        row["batch_us"] = time_call(run_backend("batch"), repeats=7,
                                    stat="min")
        row["batch_speedup_vs_pr5"] = (row["pr5_batch_us"]
                                       / row["batch_us"])
        emit(f"crypto_backends/batch/N{n}", row["batch_us"],
             f"speedup_vs_pr5={row['batch_speedup_vs_pr5']:.2f}x")
        row["glv_us"] = time_call(run_backend("glv"), repeats=3,
                                  stat="min")
        emit(f"crypto_backends/glv/N{n}", row["glv_us"],
             f"speedup_vs_pr5={row['pr5_batch_us']/row['glv_us']:.2f}x")
        if have_jax:
            row["jax_warm_us"] = time_call(run_backend("jax"), repeats=3,
                                           stat="min")
            row["jax_speedup_vs_pr5"] = (row["pr5_batch_us"]
                                         / row["jax_warm_us"])
            emit(f"crypto_backends/jax/N{n}", row["jax_warm_us"],
                 f"speedup_vs_pr5={row['jax_speedup_vs_pr5']:.2f}x")
        sweep[f"N{n}"] = row
    crypto.set_backend("auto")      # run + record the calibration probe
    calib = crypto.calibration_info()
    default = crypto.get_backend()
    default_key = "jax_warm_us" if default == "jax" else f"{default}_us"
    if default_key not in sweep["N16"]:
        raise RuntimeError(
            f"default backend {default!r} was not timed at N=16 — the "
            f"acceptance metric cannot be recorded against it")
    warm16 = None
    w16 = aot.get("warm", {}).get("l16", {})
    if "first_call_s" in w16:
        warm16 = w16.get("load_s", 0.0) + w16["first_call_s"]
    out = {
        "point_backends": sweep,
        "default_backend": default,
        "calibration": calib,
        "aot": aot,
        "target": {
            "min_batch_speedup_vs_pr5_at_N32":
                MIN_BATCH_SPEEDUP_VS_PR5_AT_32,
            "measured_at_N32": sweep["N32"]["batch_speedup_vs_pr5"],
            "min_default_speedup_vs_pr4_batch_at_N16":
                MIN_DEFAULT_SPEEDUP_VS_PR4_AT_16,
            "measured_vs_pr4_at_N16":
                sweep["N16"]["pr4_affine_batch_us"]
                / sweep["N16"][default_key],
            "max_jax_warm_start_s": MAX_JAX_WARM_START_S,
            "measured_jax_warm_start_s_at_l16": warm16,
        },
    }
    if results is not None:
        results.update(out)
    return out


def bench_full_round_protocol() -> None:
    """End-to-end HCDS round among N in-process nodes (beyond-paper)."""
    from repro.core.hcds import run_hcds_round
    # distinct round numbers per invocation (HCDS state is per-round), drawn
    # from a seeded generator so the bench replays identically (RA101)
    rng = np.random.default_rng(0)
    for n in [5, 10]:
        nodes = [HCDSNode(i) for i in range(n)]
        models = [_model(64) for _ in range(n)]

        def round_():
            run_hcds_round(nodes, models, round=int(rng.integers(1 << 30)))

        us = time_call(round_, repeats=2, warmup=0)
        emit(f"hcds_full_round/N{n}", us, f"msgs={n*(n-1)*2}")


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="HCDS commit/reveal + crypto-backend benchmarks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the round-verify sweep (naive/windowed/"
                         "batch) to this JSON file (BENCH_hcds.json)")
    ap.add_argument("--crypto-json", default=None, metavar="PATH",
                    help="run the point-arithmetic backend sweep (naive/"
                         "windowed/batch/jax vs the PR-4 affine baseline) "
                         "and write it to this JSON file (BENCH_crypto.json)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="run only the round-level verify sweep(s)")
    args = ap.parse_args(argv)
    if not args.sweep_only:
        bench_commit_stage()
        bench_dverify_vs_network()
        bench_reveal_stage()
        bench_scalar_mul_backends()
        bench_full_round_protocol()
    results: dict = {}
    bench_round_verify_sweep(results)
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")
    if args.crypto_json:
        crypto_results: dict = {}
        bench_crypto_backend_sweep(crypto_results)
        Path(args.crypto_json).write_text(
            json.dumps(crypto_results, indent=2) + "\n")
        print(f"wrote {args.crypto_json}")


if __name__ == "__main__":
    main()
