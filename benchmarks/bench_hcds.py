"""Paper Figs. 4-5: HCDS Commit/Reveal computation cost.

Fig. 4(a): H + DSign cost vs (random nonce length × model complexity)
Fig. 4(b): DVerify cost vs network size N
Fig. 5(a): Reveal cost vs (N × nonce length)
Fig. 5(b): Reveal cost vs (N × model complexity)

Model complexity is swept exactly as in the paper: the MLP hidden layer
width (§7.2, "we change the number of neurons in the hidden layer").

Beyond-paper: ``bench_round_verify_sweep`` times one round's worth of
signature verification — the N×(N−1) commit-envelope checks every PoFEL
round performs — under each crypto backend (``naive`` double-and-add,
``windowed`` per-message tables, ``batch`` dedup + randomized-linear-
combination), and ``--json`` records the sweep as
``benchmarks/BENCH_hcds.json`` so the crypto wall-time trajectory
accumulates per PR next to ``BENCH_consensus_overhead.json``.

``bench_crypto_backend_sweep`` (``--crypto-json``, recorded as
``benchmarks/BENCH_crypto.json``) is the point-arithmetic sweep for the
Jacobian/JAX rework: every backend (naive / windowed / batch / jax) at
N ∈ {4, 8, 16, 32}, measured against an in-process reconstruction of
PR 4's *affine* batch path (``curve.affine_*`` — one modular inversion
per point add), so the speedup is apples-to-apples on the machine that
ran the sweep. The acceptance bar is the default backend ≥2.5× over the
PR-4 affine batch at N=16.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from collections import OrderedDict

from benchmarks.common import emit, time_call
from repro.core import crypto
from repro.core.crypto import curve
from repro.core.envelope import SignedEnvelope
from repro.core.hcds import HCDSNode
from repro.core.serialization import serialize_pytree
from repro.models.mlp import MLPConfig, mlp_init

NONCE_LENS = [16, 64, 256, 1024]
HIDDEN = [64, 128, 256]
NET_SIZES = [10, 25, 50]
ROUND_SIZES = [4, 8, 16, 32]    # N for the round-level verify sweep
NAIVE_MAX_N = 8                 # double-and-add at N=32 would take minutes
MIN_BATCH_SPEEDUP_AT_16 = 3.0   # acceptance bar: batch vs windowed, N=16
# acceptance bar for the Jacobian/JAX PR: default backend vs PR-4's
# affine batch path, round-level verify at N=16
MIN_DEFAULT_SPEEDUP_VS_PR4_AT_16 = 2.5


def _model(hidden: int):
    return mlp_init(MLPConfig(hidden=hidden), jax.random.key(0))


def bench_commit_stage() -> None:
    """Fig. 4(a): time of H(r‖w) + DSign vs nonce length and model size."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    for hidden in HIDDEN:
        model_bytes = serialize_pytree(_model(hidden))
        for nlen in NONCE_LENS:
            nonce = crypto.random_nonce(nlen)

            def commit():
                d = crypto.sha256_digest(nonce, model_bytes)
                crypto.dsign(d, kp.private_key)

            us = time_call(commit, repeats=5)
            emit(f"hcds_commit/h{hidden}/nonce{nlen}", us,
                 f"model_bytes={len(model_bytes)}")


def bench_dverify_vs_network() -> None:
    """Fig. 4(b): DVerify cost grows linearly with N."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    d = crypto.sha256_digest(b"digest")
    tag = crypto.dsign(d, kp.private_key)
    for n in NET_SIZES:
        def verify_all():
            for _ in range(n - 1):
                assert crypto.dverify(tag, kp.public_key, d)

        us = time_call(verify_all, repeats=3)
        emit(f"hcds_commit_verify/N{n}", us, f"per_node={us/(n-1):.1f}us")


def bench_reveal_stage() -> None:
    """Fig. 5: Reveal = hash recompute + DVerify per peer, vs N and model."""
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    for hidden in [64, 256]:
        model_bytes = serialize_pytree(_model(hidden))
        nonce = crypto.random_nonce(32)
        d = crypto.sha256_digest(nonce, model_bytes)
        tag = crypto.dsign(d, kp.private_key)
        for n in NET_SIZES:
            def reveal_all():
                for _ in range(n - 1):
                    dd = crypto.sha256_digest(nonce, model_bytes)
                    assert dd == d
                    assert crypto.dverify(tag, kp.public_key, dd)

            us = time_call(reveal_all, repeats=3)
            emit(f"hcds_reveal/h{hidden}/N{n}", us, f"per_node={us/(n-1):.1f}us")


def bench_scalar_mul_backends() -> None:
    """Before/after for the windowed-table optimization (ROADMAP: pure-Python
    ECDSA dominates the consensus share of a round).

    * ``naive``    — double-and-add, the pre-optimization baseline;
    * ``windowed`` — 4-bit fixed-window table (the live path for the base
      point and, via the per-key cache, for repeated verifies);
    * ``verify_cold/warm`` — DVerify with an empty vs populated public-key
      table cache (one consensus round re-verifies each key O(N) times, so
      the warm number is the steady-state cost).
    """
    kp = crypto.ECDSAKeyPair.generate(b"bench")
    k = kp.private_key
    us = time_call(lambda: crypto._point_mul_naive(k, (crypto._GX, crypto._GY)),
                   repeats=10)
    emit("ecdsa_point_mul/naive", us)
    table = crypto._g_table()
    us_w = time_call(lambda: crypto._point_mul_windowed(k, table), repeats=10)
    emit("ecdsa_point_mul/windowed", us_w, f"speedup={us/us_w:.1f}x")

    d = crypto.sha256_digest(b"digest")
    tag = crypto.dsign(d, k)

    def verify_cold():
        crypto._PK_TABLES.clear()
        assert crypto.dverify(tag, kp.public_key, d)

    us_cold = time_call(verify_cold, repeats=5)
    emit("ecdsa_verify/cold_cache", us_cold)
    assert crypto.dverify(tag, kp.public_key, d)  # populate the cache
    us_warm = time_call(lambda: crypto.dverify(tag, kp.public_key, d),
                        repeats=10)
    emit("ecdsa_verify/warm_cache", us_warm, f"speedup={us_cold/us_warm:.1f}x")


def bench_round_verify_sweep(results: Optional[dict] = None) -> dict:
    """Round-level verification cost per backend at N∈{4,8,16,32}.

    The workload is exactly what one PoFEL round pays in the commit phase:
    every one of N receivers checks the other N−1 senders' commit
    envelopes — N×(N−1) (tag, PK, digest) verifications. The per-message
    backends (``naive``, ``windowed``) pay each check individually; the
    ``batch`` backend hands the same N×(N−1) item list to ``verify_batch``,
    which dedups the receiver copies to N distinct tags and folds them into
    one randomized-linear-combination equation. The acceptance bar
    (``target``) is ≥3× batch-over-windowed at N=16.
    """
    sweep: dict = {}
    for n in ROUND_SIZES:
        kps = [crypto.ECDSAKeyPair.generate(b"rv" + bytes([i]))
               for i in range(n)]
        envs = [SignedEnvelope.seal(
            "commit", 0, i, crypto.sha256_digest(b"model", bytes([i])),
            kps[i].private_key) for i in range(n)]
        # one item per (receiver, sender) pair — the round's real workload
        items = [(envs[s].signature, kps[s].public_key,
                  envs[s].signing_digest())
                 for r in range(n) for s in range(n) if s != r]
        row: dict = {"n_nodes": n, "verifications": len(items)}

        def per_message(backend):
            def run():
                if not crypto.verify_batch(items, backend=backend).ok:
                    raise RuntimeError(
                        f"backend {backend!r} rejected a valid batch")
            return run

        if n <= NAIVE_MAX_N:
            row["naive_us"] = time_call(per_message("naive"), repeats=1,
                                        warmup=0)
            emit(f"hcds_round_verify/naive/N{n}", row["naive_us"])
        row["windowed_us"] = time_call(per_message("windowed"), repeats=3)
        emit(f"hcds_round_verify/windowed/N{n}", row["windowed_us"])
        row["batch_us"] = time_call(per_message("batch"), repeats=3)
        row["batch_speedup_vs_windowed"] = (row["windowed_us"]
                                            / row["batch_us"])
        emit(f"hcds_round_verify/batch/N{n}", row["batch_us"],
             f"speedup_vs_windowed={row['batch_speedup_vs_windowed']:.1f}x")
        sweep[f"N{n}"] = row
    out = {
        "round_verify": sweep,
        "target": {"min_batch_speedup_vs_windowed_at_N16":
                   MIN_BATCH_SPEEDUP_AT_16,
                   "measured_at_N16":
                   sweep["N16"]["batch_speedup_vs_windowed"]},
    }
    if results is not None:
        results.update(out)
    return out


def _round_items(n: int):
    """One round's verification workload: every one of N receivers checks
    the other N−1 senders' commit envelopes."""
    kps = [crypto.ECDSAKeyPair.generate(b"cb" + bytes([i])) for i in range(n)]
    envs = [SignedEnvelope.seal(
        "commit", 0, i, crypto.sha256_digest(b"model", bytes([i])),
        kps[i].private_key) for i in range(n)]
    return [(envs[s].signature, kps[s].public_key, envs[s].signing_digest())
            for r in range(n) for s in range(n) if s != r]


def _pr4_affine_verify_batch(items) -> bool:
    """PR 4's ``batch`` path, reconstructed from the affine baseline ops
    (``curve.affine_*``): dedup + ONE randomized-linear-combination
    equation where every point add pays a modular inversion. Timed in the
    same process as the Jacobian/JAX backends so the recorded speedups are
    hardware-independent ratios, not cross-machine folklore."""
    distinct: "OrderedDict[tuple, None]" = OrderedDict()
    for tag, pk, d in items:
        distinct.setdefault((tuple(tag), pk, d), None)
    sg = 0
    acc = curve.INF
    r_terms = []
    for (tag, pk, d) in distinct:
        sig = crypto.Signature(*tag)
        R = crypto._recover_R(sig)
        assert R is not None
        w = crypto._inv_mod(sig.s, crypto._N)
        a = crypto._rlc_coefficient()
        sg = (sg + a * (crypto._bits2int(d) * w % crypto._N)) % crypto._N
        u2 = sig.r * w % crypto._N
        acc = curve.affine_point_add(
            acc, curve.affine_point_mul_windowed(a * u2 % crypto._N,
                                                 curve.pk_table(pk)))
        r_terms.append((a, (R[0], (-R[1]) % crypto._P)))
    acc = curve.affine_point_add(
        acc, curve.affine_point_mul_windowed(sg, curve.g_table()))
    acc = curve.affine_point_add(acc, curve.affine_multi_scalar(r_terms))
    return curve.is_inf(acc)


def bench_crypto_backend_sweep(results: Optional[dict] = None) -> dict:
    """Point-arithmetic backend sweep (BENCH_crypto.json).

    Round-level ``verify_batch`` cost per backend at N ∈ {4, 8, 16, 32},
    plus the in-process PR-4 affine batch baseline. ``jax`` is warmed
    first (one compile per lane bucket — recorded separately as
    ``jax_compile_s``) so the steady-state number is what a long-running
    round pipeline would see.
    """
    try:
        crypto._get_ops("jax")
        have_jax = True
    except Exception as e:          # jax-less installs still get the sweep
        have_jax = False
        emit("crypto_backends/jax", 0.0, f"unavailable: {e}")
    sweep: dict = {}
    jax_compile_s = {}
    for n in ROUND_SIZES:
        items = _round_items(n)
        row: dict = {"n_nodes": n, "verifications": len(items)}

        def run_backend(backend):
            # explicit raise, not assert: the timed workload must survive
            # `python -O`, and a backend wrongly rejecting the valid batch
            # must poison the sweep instead of the recorded numbers
            def run():
                if not crypto.verify_batch(items, backend=backend).ok:
                    raise RuntimeError(
                        f"backend {backend!r} rejected a valid batch")
            return run

        def run_pr4_baseline():
            if not _pr4_affine_verify_batch(items):
                raise RuntimeError("PR-4 affine baseline rejected a "
                                   "valid batch")

        if n <= NAIVE_MAX_N:
            row["naive_us"] = time_call(run_backend("naive"), repeats=1,
                                        warmup=1)
            emit(f"crypto_backends/naive/N{n}", row["naive_us"])
        row["windowed_us"] = time_call(run_backend("windowed"), repeats=3)
        emit(f"crypto_backends/windowed/N{n}", row["windowed_us"])
        row["pr4_affine_batch_us"] = time_call(run_pr4_baseline, repeats=3)
        emit(f"crypto_backends/pr4_affine_batch/N{n}",
             row["pr4_affine_batch_us"])
        row["batch_us"] = time_call(run_backend("batch"), repeats=3)
        row["batch_speedup_vs_pr4"] = (row["pr4_affine_batch_us"]
                                       / row["batch_us"])
        emit(f"crypto_backends/batch/N{n}", row["batch_us"],
             f"speedup_vs_pr4={row['batch_speedup_vs_pr4']:.1f}x")
        if have_jax:
            t0 = time.perf_counter()
            run_backend("jax")()        # first call compiles this bucket
            jax_compile_s[f"N{n}"] = time.perf_counter() - t0
            row["jax_us"] = time_call(run_backend("jax"), repeats=3)
            row["jax_speedup_vs_pr4"] = (row["pr4_affine_batch_us"]
                                         / row["jax_us"])
            emit(f"crypto_backends/jax/N{n}", row["jax_us"],
                 f"speedup_vs_pr4={row['jax_speedup_vs_pr4']:.1f}x")
        sweep[f"N{n}"] = row
    default = crypto.get_backend()
    if f"{default}_us" not in sweep["N16"]:
        raise RuntimeError(
            f"default backend {default!r} was not timed at N=16 — the "
            f"acceptance metric cannot be recorded against it")
    measured = sweep["N16"]["pr4_affine_batch_us"] / sweep["N16"][f"{default}_us"]
    out = {
        "point_backends": sweep,
        "default_backend": default,
        "jax_compile_s": jax_compile_s,
        "target": {
            "min_default_speedup_vs_pr4_batch_at_N16":
                MIN_DEFAULT_SPEEDUP_VS_PR4_AT_16,
            "measured_at_N16": measured,
        },
    }
    if results is not None:
        results.update(out)
    return out


def bench_full_round_protocol() -> None:
    """End-to-end HCDS round among N in-process nodes (beyond-paper)."""
    from repro.core.hcds import run_hcds_round
    # distinct round numbers per invocation (HCDS state is per-round), drawn
    # from a seeded generator so the bench replays identically (RA101)
    rng = np.random.default_rng(0)
    for n in [5, 10]:
        nodes = [HCDSNode(i) for i in range(n)]
        models = [_model(64) for _ in range(n)]

        def round_():
            run_hcds_round(nodes, models, round=int(rng.integers(1 << 30)))

        us = time_call(round_, repeats=2, warmup=0)
        emit(f"hcds_full_round/N{n}", us, f"msgs={n*(n-1)*2}")


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="HCDS commit/reveal + crypto-backend benchmarks")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the round-verify sweep (naive/windowed/"
                         "batch) to this JSON file (BENCH_hcds.json)")
    ap.add_argument("--crypto-json", default=None, metavar="PATH",
                    help="run the point-arithmetic backend sweep (naive/"
                         "windowed/batch/jax vs the PR-4 affine baseline) "
                         "and write it to this JSON file (BENCH_crypto.json)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="run only the round-level verify sweep(s)")
    args = ap.parse_args(argv)
    if not args.sweep_only:
        bench_commit_stage()
        bench_dverify_vs_network()
        bench_reveal_stage()
        bench_scalar_mul_backends()
        bench_full_round_protocol()
    results: dict = {}
    bench_round_verify_sweep(results)
    if args.json:
        Path(args.json).write_text(json.dumps(results, indent=2) + "\n")
        print(f"wrote {args.json}")
    if args.crypto_json:
        crypto_results: dict = {}
        bench_crypto_backend_sweep(crypto_results)
        Path(args.crypto_json).write_text(
            json.dumps(crypto_results, indent=2) + "\n")
        print(f"wrote {args.crypto_json}")


if __name__ == "__main__":
    main()
