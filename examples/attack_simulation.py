"""Adversary simulation (paper §3.2 + §7.4):

1. Model plagiarism — a BCFL node copies a peer's FEL model; HCDS
   rejects the duplicate reveal.
2. Bribery voting — colluding nodes vote a fixed target (TA) or randomly
   (RA); BTSV down-weights them and the honest leader still wins.

Run:  PYTHONPATH=src python examples/attack_simulation.py
"""

import numpy as np

from repro.core.consensus import PoFELConsensus
from repro.core.hcds import HCDSNode

rng = np.random.default_rng(0)
N = 10


def make_models(n, d=256):
    return [{"w": rng.normal(size=(d,)).astype(np.float32)} for _ in range(n)]


# ---------------------------------------------------------------------------
print("=== 1. Model plagiarism vs HCDS ===")
nodes = [HCDSNode(i) for i in range(3)]
models = make_models(3)
models[2] = models[0]                       # node 2 plagiarizes node 0
pks = {n.node_id: n.keypair.public_key for n in nodes}
commits = [n.commit(m, 0) for n, m in zip(nodes, models)]
for c in commits:
    for n in nodes:
        if n.node_id != c.node_id:
            n.receive_commit(c, pks[c.node_id])
reveals = [n.reveal(0) for n in nodes]
receiver = nodes[1]
print("victim reveal   :", receiver.receive_reveal(reveals[0], pks[0]).reason)
res = receiver.receive_reveal(reveals[2], pks[2])
print("plagiarist reveal:", res.reason, "accepted =", res.accepted)
assert not res.accepted

# ---------------------------------------------------------------------------
print("\n=== 2. Bribery voting vs BTSV ===")
models = make_models(N)
for attack in ("targeted", "random"):
    consensus = PoFELConsensus(N)
    n_mal = 3

    def hook(i, honest_vote, preds, attack=attack):
        if i >= N - n_mal:
            vote = 0 if attack == "targeted" else int(rng.integers(0, N))
            p = np.full_like(preds, (1 - 0.99) / (N - 1))
            p[vote] = 0.99
            return vote, p
        return honest_vote, preds

    leaders = []
    for k in range(12):
        rec = consensus.run_round(models, [10.0] * N, vote_hook=hook)
        leaders.append(rec.leader_id)
    w = np.asarray(rec.btsv.weights)
    honest = int(np.argmax(rec.similarities))
    print(f"{attack:8s} attack: leaders={leaders}")
    print(f"          mean WV honest={w[:N-n_mal].mean():.3f} "
          f"malicious={w[-n_mal:].mean():.3f} → final leader "
          f"{leaders[-1]} (honest argmax = {honest})")
    assert leaders[-1] == honest
print("\nBTSV suppressed both attacks ✓")
