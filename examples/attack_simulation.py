"""Adversary simulation (paper §3.2 + §7.4) — a thin wrapper over the
``repro.sim`` scenario registry:

1. Model plagiarism — a BCFL node copies a peer's FEL model; HCDS
   rejects the duplicate reveal every round.
2. Bribery voting — colluding nodes vote a fixed target (TA) or randomly
   (RA); BTSV down-weights them and the honest argmax keeps winning.

Run:  PYTHONPATH=src python examples/attack_simulation.py

The CI-enforced versions of these assertions live in
``tests/test_attacks.py``; the scenario registry and the report schema
are documented in ``benchmarks/README.md`` ("The repro.sim scenario
registry"). Add your own attacks by registering a ``sim.Scenario`` with
adversaries from ``repro.sim.adversary``.
"""

from repro import sim

for name in ("plagiarist", "bribery_targeted", "bribery_random"):
    sc = sim.get_scenario(name)
    print(f"=== {name} ===\n    {sc.description}")
    report = sim.run_scenario(name, seed=0)
    print(f"    {report.summary()}")
    assert report.liveness and report.safety_violations == 0

    if name == "plagiarist":
        plag = sc.adversaries[0].node_id
        reasons = {r.round: r.rejected.get(plag) for r in report.rounds}
        print(f"    plagiarist node {plag} rejected: {reasons}")
        assert all(v == "plagiarized-model" for v in reasons.values())
        assert report.honest_leader_rate == 1.0
    else:
        # the bribed votes never displaced the honest similarity argmax
        assert report.argmax_leader_rate == 1.0

print("\nHCDS + BTSV suppressed all three attacks ✓")
