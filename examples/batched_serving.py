"""Batched serving through the ServingEngine: mixed prompt lengths, EOS,
and nucleus sampling (reduced config on CPU; the same decode_step lowers
for decode_32k / long_500k on the production mesh).

Run:  PYTHONPATH=src python examples/batched_serving.py [--arch yi-6b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.model_api import Model
from repro.serving import GenerationRequest, SamplerConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b",
                    choices=[a for a in ARCH_IDS if a != "mnist-mlp"])
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-p", type=float, default=0.9)
    args = ap.parse_args()

    model = Model(get_config(args.arch).reduced())
    params = model.init(jax.random.key(0))
    engine = ServingEngine(
        model, params,
        SamplerConfig(temperature=args.temperature, top_p=args.top_p))

    rng = np.random.default_rng(0)
    requests = [
        GenerationRequest(0, rng.integers(0, 500, 5).astype(np.int32),
                          max_new_tokens=12),
        GenerationRequest(1, rng.integers(0, 500, 17).astype(np.int32),
                          max_new_tokens=8),
        GenerationRequest(2, rng.integers(0, 500, 9).astype(np.int32),
                          max_new_tokens=12, eos_token=7),
    ]
    t0 = time.perf_counter()
    completions = engine.generate(requests)
    dt = time.perf_counter() - t0

    total = sum(len(c.tokens) for c in completions)
    print(f"arch={args.arch} (reduced) — {len(requests)} requests, "
          f"{total} tokens in {dt*1e3:.0f} ms")
    for c in completions:
        print(f"  req{c.request_id} [{c.finished_by:6s}]: {c.tokens}")


if __name__ == "__main__":
    main()
