"""Batched serving example: prefill + autoregressive decode with KV caches
(reduced config). Exercises the same decode_step lowered by the decode_32k
and long_500k dry-run shapes.

Run:  PYTHONPATH=src python examples/serve_llm.py [--arch zamba2-7b]
"""

import argparse

from repro.launch.serve import serve_reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-7b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    serve_reduced(args.arch, batch=args.batch, prompt_len=24, gen=12,
                  seed=0, temperature=0.0)


if __name__ == "__main__":
    main()
