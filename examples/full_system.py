"""The complete BHFL workflow (paper §3.1, all four procedures), driven
through the ``repro.api`` facade:

1. Task Publication — a model owner publishes a learning task; nodes
   evaluate and accept (participation constraint).
2. Incentive Mechanism — two-stage Stackelberg game fixes δ* and f_i*.
3. Federated Edge Learning — clusters train with FedAvg.
4. Global Aggregation + PoFEL consensus — HCDS, ME voting, BTSV tally,
   block minting (the five-phase pipeline of ``repro.core.phases``);
   leader + FEL rewards settle per round; the task terminates at target
   loss or max rounds.

``api.run_bhfl`` composes all four; everything it returns (agreement,
reward ledger, runtime/consensus/ledgers, per-round metrics) is inspected
below.

Run:  PYTHONPATH=src python examples/full_system.py
"""

from repro import api

N_NODES = 6

# --- 1. Task Publication ----------------------------------------------------
task = api.LearningTask(
    task_id="mnist-mlp-0", publisher_id="model-owner-7",
    description="10-class digit classification, MLP 784-128-10",
    target_loss=1.55, max_rounds=12, block_reward=10.0)
print(f"published task {task.task_id} (digest {task.digest()[:16]}…)")

# --- 2-4. negotiation + hierarchy + FEL/consensus rounds ---------------------
run = api.run_bhfl(
    task, model="mlp",
    data=api.make_mnist_like(n_train=4000, n_test=600),
    n_nodes=N_NODES, clients_per_node=4, fel_iterations=2,
    on_round=lambda m: print(f"round {m.round:2d}  leader={m.leader_id}  "
                             f"acc={m.test_accuracy:.3f}  "
                             f"loss={m.test_loss:.3f}"))

agreement = run.agreement
print(f"negotiated: {len(agreement.participants)} participants, "
      f"δ*={agreement.delta_star:.0f}, "
      f"f*=[{min(agreement.f_star.values()):.1f}.."
      f"{max(agreement.f_star.values()):.1f}]")
if run.history[-1].test_loss <= task.target_loss:
    print(f"target loss {task.target_loss} reached — task complete")

# --- settlement ---------------------------------------------------------------
print("\nchain verified:", run.chain_valid, "height:", run.chain_height)
print("total rewards per node:",
      {i: round(v, 1) for i, v in run.rewards.totals().items()})
first = agreement.participants[0]
split = run.rewards.client_split(
    first,
    {c.client_id: float(c.data_size)
     for c in run.runtime.clusters[first].clients})
print(f"node {first} → client split "
      f"(∝ contribution): {[round(v, 2) for v in split.values()]}")
