"""The complete BHFL workflow (paper §3.1, all four procedures):

1. Task Publication — a model owner publishes a learning task; nodes
   evaluate and accept (participation constraint).
2. Incentive Mechanism — two-stage Stackelberg game fixes δ* and f_i*.
3. Federated Edge Learning — clusters train with FedAvg.
4. Global Aggregation + PoFEL consensus — HCDS, ME voting, BTSV tally,
   block minting; leader + FEL rewards settle per round; the task
   terminates at target loss or max rounds.

Run:  PYTHONPATH=src python examples/full_system.py
"""

import numpy as np

from repro.data.synthetic import make_mnist_like
from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime
from repro.fl.hierarchy import build_hierarchy
from repro.fl.task import LearningTask, RewardLedger, negotiate_task

N_NODES = 6

# --- 1. Task Publication ----------------------------------------------------
task = LearningTask(
    task_id="mnist-mlp-0", publisher_id="model-owner-7",
    description="10-class digit classification, MLP 784-128-10",
    target_loss=1.55, max_rounds=12, block_reward=10.0)
print(f"published task {task.task_id} (digest {task.digest()[:16]}…)")

# --- 2. Incentive Mechanism ---------------------------------------------------
rng = np.random.default_rng(0)
gamma = {i: float(g) for i, g in enumerate(rng.uniform(0.008, 0.02, N_NODES))}
mu = {i: 5.0 for i in range(N_NODES)}
agreement = negotiate_task(task, list(range(N_NODES)), gamma, mu)
print(f"negotiated: {len(agreement.participants)} participants, "
      f"δ*={agreement.delta_star:.0f}, "
      f"f*=[{min(agreement.f_star.values()):.1f}.."
      f"{max(agreement.f_star.values()):.1f}]")
rewards = RewardLedger(agreement)

# --- 3+4. FEL + consensus rounds until termination ---------------------------
train, test = make_mnist_like(n_train=4000, n_test=600)
cfg = BHFLConfig(n_nodes=N_NODES, clients_per_node=4, fel_iterations=2)
runtime = BHFLRuntime(build_hierarchy(train, N_NODES, 4, "iid"), cfg, test)

for k in range(task.max_rounds):
    m = runtime.run_round()
    rewards.settle_round(m.leader_id)
    print(f"round {m.round:2d}  leader={m.leader_id}  "
          f"acc={m.test_accuracy:.3f}  loss={m.test_loss:.3f}")
    if m.test_loss <= task.target_loss:
        print(f"target loss {task.target_loss} reached — task complete")
        break

# --- settlement ---------------------------------------------------------------
print("\nchain verified:", runtime.consensus.ledgers[0].verify_chain(),
      "height:", runtime.consensus.ledgers[0].height)
print("total rewards per node:",
      {i: round(v, 1) for i, v in rewards.totals().items()})
split = rewards.client_split(
    agreement.participants[0],
    {c.client_id: float(c.data_size)
     for c in runtime.clusters[agreement.participants[0]].clients})
print(f"node {agreement.participants[0]} → client split "
      f"(∝ contribution): {[round(v, 2) for v in split.values()]}")
