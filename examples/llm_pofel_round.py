"""PoFEL at LLM scale (reduced configs on CPU): the in-graph consensus
trainer from repro.fl.pofel_trainer runs real rounds — per-cluster FedSGD
on divergent replicas, Eq. 1/Eq. 2 consensus, BTSV leader election, and a
host-side ledger — for any assigned architecture.

Run:  PYTHONPATH=src python examples/llm_pofel_round.py [--arch rwkv6-1.6b]
"""

import argparse

from repro.launch.train import train_reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--outer", default="nesterov", choices=["sgd1", "nesterov"])
    args = ap.parse_args()
    train_reduced(args.arch, steps=args.steps, n_clusters=4, batch=8,
                  seq=64, seed=0, outer=args.outer)


if __name__ == "__main__":
    main()
