"""Quickstart: the PoFEL consensus in 60 lines, on the ``repro.api`` facade.

Five BCFL nodes train tiny local models, run one full PoFEL round through
the five-phase pipeline (HCDS commit/reveal → ME similarity voting → vote
submission → BTSV tally → block mint), and every ledger ends up with the
same verified block. A phase hook watches the pipeline run — the API for
experiments that tap individual protocol stages.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro import api
from repro.models.mlp import MLPConfig, mlp_init

N_NODES = 5

# 1. Each edge server trained an intermediate FEL model (here: random init
#    + a node-specific perturbation standing in for local training).
cfg = MLPConfig(hidden=32)
base = mlp_init(cfg, jax.random.key(0))
rng = np.random.default_rng(0)
models = [
    jax.tree.map(lambda p: np.asarray(p) + 0.01 * rng.normal(size=p.shape)
                 .astype(np.float32), base)
    for _ in range(N_NODES)
]
data_sizes = [100.0, 150.0, 120.0, 80.0, 200.0]   # |DS_m| per cluster

# 2. One PoFEL consensus round (Alg. 1) — five phases over a RoundContext.
consensus = api.PoFELConsensus(N_NODES)
print("phases:", [p.name for p in consensus.phases])
consensus.add_phase_hook(
    "*", lambda name, ctx: print(f"  ✓ {name}"), when="after")
record = consensus.run_round(models, data_sizes)

print("cosine similarities s_m:", np.round(record.similarities, 5))
print("votes:", record.votes.tolist())
print(f"leader e*(k) = node {record.leader_id}")
print(f"BTS vote weights: {np.round(np.asarray(record.btsv.weights), 3)}")

# 3. Every node's ledger now holds the identical signed block.
for ledger in consensus.ledgers:
    assert ledger.height == 1 and ledger.verify_chain()
block = consensus.chain[0]
print(f"block 0: leader={block.leader_id} "
      f"digest[gw]={block.global_model_digest[:16]}… "
      f"signature valid={block.verify_signature(consensus.public_keys[block.leader_id])}")
print("all ledgers consistent ✓")

# 4. The same protocol drives a full learning task in one call:
#        api.run_bhfl(model="mlp" | "transformer" | "rwkv6", ...)
#    — see examples/full_system.py and examples/bhfl_train.py.
#    Fast path: api.run_bhfl(..., engine="batched") (or
#    BHFLConfig(engine="batched")) runs the whole FEL phase of each round
#    as ONE jitted device program (repro.fl.batched_fel) — same numbers,
#    ≥5x less wall time on CPU at paper scale, more on accelerators.
