"""End-to-end driver (paper §7.1 setup, scaled to this container):

Blockchain-based hierarchical FL on MNIST-like data — N edge clusters × 5
clients each train an MLP with FedAvg; every BCFL round runs the full
PoFEL consensus (HCDS + ME + BTSV) and appends a block. Trains for a few
hundred federated client-steps and reports global-model accuracy, leader
rotation, and chain integrity.

Run:  PYTHONPATH=src python examples/bhfl_train.py [--nodes 8] [--rounds 10]
"""

import argparse

import numpy as np

from repro.data.synthetic import make_mnist_like
from repro.fl.hierarchy import build_hierarchy
from repro.fl.hfl_runtime import BHFLConfig, BHFLRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--fel-iters", type=int, default=3)
    ap.add_argument("--distribution", default="iid",
                    choices=["iid", "label", "dirichlet"])
    args = ap.parse_args()

    train, test = make_mnist_like(n_train=6000, n_test=1000)
    cfg = BHFLConfig(n_nodes=args.nodes, clients_per_node=args.clients,
                     fel_iterations=args.fel_iters)
    clusters = build_hierarchy(train, args.nodes, args.clients,
                               args.distribution)
    rt = BHFLRuntime(clusters, cfg, test)

    print(f"BHFL: {args.nodes} BCFL nodes × {args.clients} clients, "
          f"{args.distribution} data, {args.fel_iters} FEL iters/round")
    for _ in range(args.rounds):
        m = rt.run_round()
        print(f"round {m.round:3d}  leader={m.leader_id}  "
              f"acc={m.test_accuracy:.3f}  loss={m.test_loss:.3f}")

    counts = rt.leader_counts()
    print("\nleader counts (Fig. 6b):", counts)
    assert rt.consensus.ledgers[0].verify_chain()
    print(f"chain verified at height {rt.consensus.ledgers[0].height} ✓")
    first, last = rt.history[0], rt.history[-1]
    print(f"accuracy {first.test_accuracy:.3f} → {last.test_accuracy:.3f}")


if __name__ == "__main__":
    main()
