"""End-to-end driver (paper §7.1 setup, scaled to this container):

Blockchain-based hierarchical FL on synthetic data via ``repro.api`` —
N edge clusters × 5 clients each train with FedAvg; every BCFL round runs
the full five-phase PoFEL consensus (HCDS → ME → votes → BTSV → block)
and appends a block. The ``--model`` flag swaps the workload between the
paper's MNIST MLP and the reduced-scale transformer / RWKV6 LMs — same
consensus path, different ``ModelAdapter``.

Run:  PYTHONPATH=src python examples/bhfl_train.py [--nodes 8] [--rounds 10]
      PYTHONPATH=src python examples/bhfl_train.py --model rwkv6 --rounds 3
"""

import argparse

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "transformer", "rwkv6"])
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--fel-iters", type=int, default=3)
    ap.add_argument("--distribution", default="iid",
                    choices=["iid", "label", "dirichlet"])
    args = ap.parse_args()

    if args.model == "mlp":
        data = api.make_mnist_like(n_train=6000, n_test=1000)
    else:
        data = api.make_token_dataset(n_seqs=512, seq_len=16, vocab_size=256)
        if args.distribution != "iid":
            ap.error("label-aware partitions need image labels; LM models "
                     "support --distribution iid")

    print(f"BHFL[{args.model}]: {args.nodes} BCFL nodes × {args.clients} "
          f"clients, {args.distribution} data, {args.fel_iters} FEL "
          f"iters/round")
    run = api.run_bhfl(
        model=args.model, data=data,
        n_nodes=args.nodes, clients_per_node=args.clients,
        fel_iterations=args.fel_iters, rounds=args.rounds,
        distribution=args.distribution,
        on_round=lambda m: print(f"round {m.round:3d}  leader={m.leader_id}  "
                                 f"acc={m.test_accuracy:.3f}  "
                                 f"loss={m.test_loss:.3f}"))

    print("\nleader counts (Fig. 6b):", run.leader_counts)
    assert run.chain_valid
    print(f"chain verified at height {run.chain_height} ✓")
    first, last = run.history[0], run.history[-1]
    print(f"accuracy {first.test_accuracy:.3f} → {last.test_accuracy:.3f}")


if __name__ == "__main__":
    main()
